"""Observability subsystem (repro.obs + the round telemetry contract).

ISSUE 7 invariants:
  * wire-byte identities hold across the full topology x codec x faults
    matrix: total == sum of the per-stream splits, and total == up + down
    (server/async: pushes and replies are distinct payloads) or
    total == up == down (p2p edges count once) — including push_sum's
    delivered-priced accounting,
  * every localsgd round emits the UNIFORM metric schema
    (obs.round_metric_keys) regardless of topology/codec/faults —
    participation/delivery_rate are 1.0 on a clean network, not absent,
  * a trace written through obs.Trace round-trips through
    obs.report.load/check/summarize: schema-valid, monotone rounds,
    fenced phase durations,
  * consensus distance ||x_g - mean||^2 matches replicated-vs-sharded
    <= 1e-5 on the forced-8-device mesh (shardexec.consensus_sq_groups).

The 8-device tests re-run in a forced-host child under plain tier-1
(same driver pattern as test_shardexec).
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import comm, obs, optim
from repro.core import localsgd as lsgd
from repro.obs import report
from repro.optim import packing

HAVE8 = jax.device_count() >= 8
needs8 = pytest.mark.skipif(not HAVE8, reason="needs 8 devices "
                            "(forced-host child process runs these)")

G = 4


def quad_loss(params, batch):
    r = batch["A"] @ params["w"] - batch["b"]
    return 0.5 * jnp.sum(r ** 2) + 0.1 * jnp.sum(params["u"] ** 2)


def make_problem(key, g=G, r=4, d=6):
    ks = jax.random.split(key, 4)
    A = jax.random.normal(ks[0], (g, r, d)) / np.sqrt(d)
    w_star = jax.random.normal(ks[1], (d,))
    batch = {"A": A, "b": jnp.einsum("grd,d->gr", A, w_star)}
    params = {"w": jax.random.normal(ks[2], (d,)),
              "u": jax.random.normal(ks[3], (2, 3))}
    return params, batch


# ---------------------------------------------------------------------------
# wire-byte identities across the topology x codec x faults matrix
# ---------------------------------------------------------------------------

TOPOLOGIES = ["server", "ring", "gossip", "async_stale", "push_sum"]
CODECS = ["fp32", "fp16", "bf16", "int8", "topk"]
FAULTS = [{}, {"drop_rate": 0.05, "fault_seed": 3},
          {"stall_rate": 0.1, "fault_seed": 7}]


def _matrix():
    for topo in TOPOLOGIES:
        for codec in CODECS:
            for faults in FAULTS:
                yield topo, codec, faults


def test_wire_bytes_identities_across_matrix():
    """Static accounting property: for every buildable combo (refused
    ones — push_sum+int8/topk, async_stale+topk — are skipped) the
    per-stream splits sum to the total, and the total follows the
    counting rule: p2p edge payloads count ONCE (total == up == down),
    server/async pushes and replies are distinct (total == up + down)."""
    n, msizes = 10_000, {"mu": 10_000}
    checked = 0
    for topo, codec, faults in _matrix():
        try:
            ex = comm.get_exchange(topo, codec, G, **faults)
        except NotImplementedError:
            continue
        for ms in ({}, msizes):
            by = ex.wire_bytes_by_stream(n, ms)
            total = ex.wire_bytes_per_round(n, moment_sizes=ms)
            up = ex.wire_bytes_up(n, moment_sizes=ms)
            down = ex.wire_bytes_down(n, moment_sizes=ms)
            label = f"{topo}/{codec}/{faults}/{sorted(ms)}"
            assert set(by) == {"params"} | set(ms), label
            assert total == sum(by.values()), label
            if ex.p2p:
                assert total == up == down, label
            else:
                assert total == up + down, label
            assert total > 0 and up > 0, label
        checked += 1
    # the matrix is real: every topology survives with >= 3 codecs
    assert checked >= 5 * 3


def test_push_sum_delivered_pricing_scales_wire_bytes():
    """push_sum prices DELIVERED payloads: a 20% drop rate scales the
    static per-round bytes by the expected delivery rate (and the
    payload carries the +4B weight counter per push)."""
    n = 5_000
    clean = comm.get_exchange("push_sum", "fp32", G)
    lossy = comm.get_exchange("push_sum", "fp32", G, drop_rate=0.2,
                              fault_seed=1)
    assert clean.delivery_rate == 1.0
    assert 0.0 < lossy.delivery_rate < 1.0
    b_clean = clean.wire_bytes_per_round(n)
    b_lossy = lossy.wire_bytes_per_round(n)
    assert b_lossy == pytest.approx(
        b_clean * lossy.delivery_rate / clean.delivery_rate, rel=0.01)


# ---------------------------------------------------------------------------
# uniform round-metric schema (device-side layer)
# ---------------------------------------------------------------------------

def _run_round(key, topo, codec, opt_name="sgd", packed=True, avg=False,
               rounds=1, **faults):
    params, batch = make_problem(key)
    layout = packing.layout_of(params) if packed else None
    opt = (optim.packed(opt_name, 0.05, impl="jnp") if packed
           else optim.get(opt_name, 0.05))
    ex = comm.get_exchange(topo, codec, G, **faults)
    avg = avg and ex.supports_opt_state_averaging
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2,
                              average_opt_state=avg)
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg,
                                        layout=layout, exchange=ex))
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                         exchange=ex, average_opt_state=avg)
    for _ in range(rounds):
        st, m = rnd(st, batch)
    return ex, st, m


@pytest.mark.parametrize("topo,codec,opt_name,avg,faults", [
    ("server", "fp32", "sgd", False, {}),
    ("server", "int8", "momentum", True, {"drop_rate": 0.3,
                                          "fault_seed": 1}),
    ("ring", "topk", "sgd", False, {}),
    ("push_sum", "fp16", "sgd", False, {"drop_rate": 0.1,
                                        "fault_seed": 2}),
    ("async_stale", "fp32", "adamw", True, {}),
])
def test_uniform_round_metric_schema(key, topo, codec, opt_name, avg,
                                     faults):
    """EVERY configuration emits exactly obs.round_metric_keys(streams):
    consensus pre/post, per-stream codec error, backlog, participation,
    delivery — present (and finite) even where the quantity is trivially
    zero/one, so consumers never branch on key existence."""
    ex, st, m = _run_round(key, topo, codec, opt_name=opt_name, avg=avg,
                           rounds=2, **faults)
    streams = obs.streams_of(m)
    assert "params" in streams
    assert set(m) == set(obs.round_metric_keys(streams))
    # runtime wire identities mirror the static accounting
    split = sum(int(m[f"wire_bytes/{s}"]) for s in streams)
    assert int(m["wire_bytes"]) == split
    if ex.p2p:
        assert int(m["wire_bytes"]) == int(m["wire_bytes_up"]) \
            == int(m["wire_bytes_down"])
    else:
        assert int(m["wire_bytes"]) == (int(m["wire_bytes_up"])
                                        + int(m["wire_bytes_down"]))
    # uniform defaults where the feature is off
    assert 0.0 <= float(m["participation"]) <= 1.0
    assert float(m["delivery_rate"]) == pytest.approx(ex.delivery_rate)
    if not faults:
        assert float(m["participation"]) == 1.0
    if topo != "push_sum":
        assert float(m["backlog_mass"]) == 0.0
    # consensus distance: (G,) nonnegative, and the exchange tightened it
    pre = np.asarray(m["consensus_sq"])
    post = np.asarray(m["consensus_sq_post"])
    assert pre.shape == (G,) and post.shape == (G,)
    assert np.all(pre >= 0) and np.all(post >= 0)
    # codec error mass: zero unless the codec keeps an EF residual
    err = np.asarray(m["codec_err/params"])
    assert err.shape == (G,) and np.all(err >= 0)
    if codec != "topk":
        assert np.all(err == 0)


def test_consensus_metric_tracks_drift_and_mixing(key):
    """server/fp32: the post-exchange consensus distance is ~0 (exact
    mean), the pre-exchange one is positive (groups drifted during local
    steps on different data)."""
    _, _, m = _run_round(key, "server", "fp32")
    assert float(np.max(m["consensus_sq"])) > 0
    assert float(np.max(m["consensus_sq_post"])) \
        <= 1e-10 * max(1.0, float(np.max(m["consensus_sq"])))


def test_topk_codec_err_reports_residual_mass(key):
    """topk error feedback: the round's codec_err/params equals the
    squared mass actually held in the EF residual state."""
    _, st, m = _run_round(key, "ring", "topk", rounds=2)
    res = st["comm"]["codec"]["params"]["residual"]
    want = np.sum(np.square(np.asarray(res, np.float64)),
                  axis=tuple(range(1, np.ndim(res))))
    np.testing.assert_allclose(np.asarray(m["codec_err/params"]), want,
                               rtol=1e-5)
    assert float(np.max(want)) > 0      # topk actually deferred mass


def test_pytree_round_emits_same_schema(key):
    """The per-leaf pytree engine (no layout) emits the identical
    uniform schema — per-stream keys for params + averaged moments."""
    _, _, m = _run_round(key, "server", "fp32", opt_name="adamw",
                         packed=False, avg=True)
    streams = obs.streams_of(m)
    assert set(streams) == {"params", "m", "v"}
    # the pytree engine keeps its per-step trajectory extras; the uniform
    # contract is that every obs key is PRESENT, not that nothing else is
    assert set(obs.round_metric_keys(streams)) <= set(m)


# ---------------------------------------------------------------------------
# trace round-trip (host-side layer + report)
# ---------------------------------------------------------------------------

def test_trace_roundtrip_faulty_push_sum(key, tmp_path):
    """Write a trace from a short faulty push_sum run through the real
    Trace.phase/emit_round path, re-read it with obs.report: --check
    clean, monotone rounds, phase durations present, consensus/
    participation summarized."""
    path = tmp_path / "run.jsonl"
    params, batch = make_problem(key)
    layout = packing.layout_of(params)
    opt = optim.packed("sgd", 0.05, impl="jnp")
    ex = comm.get_exchange("push_sum", "fp32", G, drop_rate=0.2,
                           fault_seed=5)
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg,
                                        layout=layout, exchange=ex))
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                         exchange=ex)
    with obs.Trace(str(path), meta={"comm": ex.name, "groups": G}) as tr:
        for n in range(4):
            with tr.phase("round") as f:
                st, m = f(rnd(st, batch))
            tr.emit_round(n, m)
    meta, records = report.load(path)
    assert report.check(meta, records) == []
    assert meta["schema"] == obs.SCHEMA_VERSION
    assert meta["comm"] == ex.name
    rounds = report.rounds_of(records)
    assert [r["round"] for r in rounds] == [0, 1, 2, 3]
    for r in rounds:
        assert r["phase_s"]["round"] >= 0.0
        assert set(obs.round_metric_keys(("params",))) <= set(r["metrics"])
    s = report.summarize(meta, records)
    assert s["n_rounds"] == 4
    assert len(s["consensus_sq"]["trajectory"]) == 4
    assert 0.0 < s["participation"]["min"] <= 1.0
    assert s["wire_bytes_total"] == 4 * int(rounds[0]["metrics"]
                                            ["wire_bytes"])
    # CLI --check exits 0 on this file
    assert report.main([str(path), "--check"]) == 0


def test_report_check_flags_broken_traces(tmp_path):
    """--check catches: missing meta, non-monotone rounds, missing
    schema keys, split/total mismatch."""
    m_ok = {k: 1.0 for k in obs.round_metric_keys(("params",))}
    m_ok.update({"wire_bytes": 8, "wire_bytes_up": 8, "wire_bytes_down": 8,
                 "wire_bytes/params": 8, "participation": 1.0})

    def write(path, lines):
        path.write_text("\n".join(json.dumps(x) for x in lines) + "\n")
        return report.check(*report.load(path))

    meta = {"kind": "meta", "schema": obs.SCHEMA_VERSION}
    rec = {"kind": "round", "round": 0, "phase_s": {"round": 0.1},
           "metrics": m_ok}
    p = tmp_path / "t.jsonl"
    assert write(p, [meta, rec]) == []
    assert any("meta" in s for s in write(p, [rec]))
    assert any("monotone" in s for s in write(
        p, [meta, rec, dict(rec, round=0)]))
    bad_keys = dict(rec, metrics={"loss": 1.0})
    assert any("missing metric keys" in s
               for s in write(p, [meta, bad_keys]))
    bad_split = dict(rec, metrics=dict(m_ok, wire_bytes=999))
    assert any("per-stream splits" in s
               for s in write(p, [meta, bad_split]))


def test_trace_null_sink_still_times(key):
    """Trace(path=None): no file I/O, but phases still fence and time —
    the launchers run one code path whether or not --trace is set."""
    tr = obs.Trace(None)
    x = jnp.zeros((256, 256))
    with tr.phase("round") as f:
        y = f(x @ x)
    rec = tr.emit_round(0, {"loss": y[0, 0]})
    assert rec["phase_s"]["round"] >= 0.0
    assert tr.n_records == 1
    tr.close()


def test_phase_timer_fences_async_dispatch():
    """The satellite-1 fix in microcosm: an unfenced delta around a
    dispatched matmul chain reads ~0; the fenced PhaseTimer waits for
    the value. (Asserting fenced >= unfenced, not absolute times —
    container clocks are noisy.)"""
    import time
    x = jnp.ones((512, 512))

    @jax.jit
    def chain(x):
        for _ in range(8):
            x = x @ x / 512.0
        return x

    chain(x).block_until_ready()          # compile outside the timers
    t0 = time.perf_counter()
    y = chain(x)
    unfenced = time.perf_counter() - t0
    with obs.PhaseTimer() as t:
        t.fence(chain(y))
    assert t.seconds >= 0.0
    jax.block_until_ready(y)
    assert unfenced >= 0.0                # smoke: both paths executed


# ---------------------------------------------------------------------------
# consensus parity replicated vs sharded (forced-8-device mesh)
# ---------------------------------------------------------------------------

def mesh8(shape=(4, 2), axes=("data", "model")):
    from jax.sharding import Mesh
    n = int(np.prod(shape))
    return Mesh(np.array(jax.devices()[:n]).reshape(shape), axes)


@needs8
def test_consensus_sq_groups_matches_flat_reference(key):
    """shardexec.consensus_sq_groups (pmean over groups + shard-local
    sq + psum over shards) against the replicated flat reduction on the
    same (G, Np) buffer: <= 1e-5 rel."""
    from repro.core.localsgd import _consensus_sq_flat
    from repro.sharding import shardexec as shx

    mesh = mesh8()
    sexec = shx.plan_for(mesh)
    params, _ = make_problem(key)
    layout = packing.shard_layout(packing.layout_of(params),
                                  sexec.n_shards)
    x = packing.pack(lsgd.replicate(params, G), layout)
    x = x + jax.random.normal(key, x.shape) * 0.1
    got = jax.jit(sexec.consensus_sq_groups(use_pallas=False))(x)
    want = jax.jit(lambda b: _consensus_sq_flat(b, False))(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5)
    assert float(np.min(want)) > 0


@needs8
def test_consensus_trajectory_parity_replicated_vs_sharded(key, tmp_path):
    """ISSUE 7 acceptance: trace a short faulty push_sum run on the
    replicated AND the sharded packed engine — the per-round consensus
    trajectories agree <= 1e-5 everywhere in the two trace files."""
    from repro.sharding import shardexec as shx

    mesh = mesh8()
    sexec = shx.plan_for(mesh)
    params, batch = make_problem(key)
    layout = packing.shard_layout(packing.layout_of(params),
                                  sexec.n_shards)
    ex = comm.get_exchange("push_sum", "fp32", G, drop_rate=0.05,
                           fault_seed=2)
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)
    traces = {}
    for tag, sx in (("replicated", None), ("sharded", sexec)):
        opt = optim.packed("sgd", 0.05, impl="jnp")
        rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg,
                                            layout=layout, exchange=ex,
                                            shardexec=sx))
        st = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                             exchange=ex)
        path = tmp_path / f"{tag}.jsonl"
        with obs.Trace(str(path), meta={"engine": tag}) as tr:
            for n in range(4):
                with tr.phase("round") as f:
                    st, m = f(rnd(st, batch))
                tr.emit_round(n, m)
        meta, records = report.load(path)
        assert report.check(meta, records) == []
        traces[tag] = report.summarize(meta, records)
    for k in ("consensus_sq",):
        a = np.asarray(traces["replicated"][k]["trajectory"])
        b = np.asarray(traces["sharded"][k]["trajectory"])
        np.testing.assert_allclose(b, a, rtol=1e-5, atol=1e-12)
    assert traces["replicated"]["participation"]["min"] \
        == pytest.approx(traces["sharded"]["participation"]["min"])


# ---------------------------------------------------------------------------
# tier-1 driver: force 8 host devices in a child process
# ---------------------------------------------------------------------------

def test_suite_under_forced_8_devices():
    """Under the plain 1-device tier-1 run, re-run this module with 8
    forced host devices in a subprocess (jax locks the device count at
    first init). CI's forced-8-device job runs the tests directly and
    skips this driver."""
    if HAVE8:
        pytest.skip("already running with 8 devices")
    if os.environ.get("REPRO_SHARDEXEC_CHILD") == "1":
        pytest.skip("child process")
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                        + env.get("XLA_FLAGS", "")).strip()
    env["REPRO_SHARDEXEC_CHILD"] = "1"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(repo, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         os.path.abspath(__file__)],
        env=env, capture_output=True, text=True, timeout=1800,
        cwd=repo)
    assert r.returncode == 0, (
        f"8-device obs suite failed:\n{r.stdout[-4000:]}"
        f"\n{r.stderr[-2000:]}")
