"""Per-architecture smoke tests: reduced variants (2 layers, d_model<=512,
<=4 experts) run one forward / train grad step / decode step on CPU with
shape and finiteness assertions."""
import jax
import jax.numpy as jnp
import pytest

from repro import optim
from repro.configs.base import ARCH_IDS, get_config
from repro.models import build_model


def make_batch(cfg, key, B=2, S=32):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.n_frames, cfg.d_model))
    return batch


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = get_config(request.param).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_forward_shapes(arch_setup, key):
    cfg, model, params = arch_setup
    batch = make_batch(cfg, key)
    x, aux = model.forward(params, batch)
    assert x.shape == (2, 32, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


def test_loss_and_grad_step(arch_setup, key):
    cfg, model, params = arch_setup
    batch = make_batch(cfg, key)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert bool(jnp.isfinite(loss)) and loss > 0
    gnorm = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
    assert bool(jnp.isfinite(gnorm)) and gnorm > 0
    opt = optim.sgd(1e-2)
    new_params, _ = opt.step(params, grads, opt.init(params))
    loss2 = model.loss(new_params, batch)
    assert bool(jnp.isfinite(loss2))
    assert loss2 < loss  # one full-batch GD step must descend


def test_decode_step(arch_setup, key):
    cfg, model, params = arch_setup
    B, W = 2, 64
    cache = model.init_cache(B, W)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    logits, new_cache = model.decode_step(
        params, cache, tok, jnp.asarray(0, jnp.int32))
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # cache structure preserved
    assert jax.tree.structure(new_cache) == jax.tree.structure(
        {k: v for k, v in cache.items()})


def test_decode_matches_prefill_next_token(arch_setup, key):
    """Greedy next-token from decode-with-cache == from a fresh forward.

    Run S tokens through decode one at a time, compare the final-position
    logits against model.logits on the same prefix."""
    cfg, model, params = arch_setup
    if cfg.family in ("vlm", "audio"):
        pytest.skip("prefix modalities differ between paths")
    B, S = 1, 8
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full = model.logits(params, {"tokens": toks})     # (B,S,V)

    cache = model.init_cache(B, S)
    logits = None
    for t in range(S):
        logits, cache = model.decode_step(
            params, cache, toks[:, t:t + 1], jnp.asarray(t, jnp.int32))
    # compare distributions at the last position
    a = jax.nn.log_softmax(full[:, -1].astype(jnp.float32))
    b = jax.nn.log_softmax(logits[:, 0].astype(jnp.float32))
    # reduced configs run bf16-free (dtype float32) so this is tight-ish
    assert jnp.max(jnp.abs(a - b)) < 5e-2, float(jnp.max(jnp.abs(a - b)))


def test_sliding_window_decode(arch_setup, key):
    """Ring-buffer cache accepts positions beyond the window."""
    cfg, model, params = arch_setup
    B, W = 1, 16
    cache = model.init_cache(B, W)
    tok = jnp.zeros((B, 1), jnp.int32)
    for pos in [0, 1, W - 1, W, W + 3]:
        logits, cache = model.decode_step(
            params, cache, tok, jnp.asarray(pos, jnp.int32))
        assert bool(jnp.all(jnp.isfinite(logits)))


def test_batched_prefill_matches_stepwise(arch_setup, key):
    """Dense/MoE families: one batched prefill == token-by-token decode
    (same cache contents -> identical next-token logits)."""
    cfg, model, params = arch_setup
    if not hasattr(model, "prefill") or cfg.family in ("vlm", "audio"):
        pytest.skip("prefill path is dense/moe only")
    B, S, W = 1, 6, 12
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    logits_pf, cache_pf = model.prefill(params, {"tokens": toks}, W)

    cache = model.init_cache(B, W)
    logits_st = None
    for t in range(S):
        logits_st, cache = model.decode_step(
            params, cache, toks[:, t:t + 1], jnp.asarray(t, jnp.int32))
    a = jax.nn.log_softmax(logits_pf[:, 0].astype(jnp.float32))
    b = jax.nn.log_softmax(logits_st[:, 0].astype(jnp.float32))
    assert float(jnp.max(jnp.abs(a - b))) < 5e-2
    # continuing decode from the prefilled cache agrees too
    nxt = jnp.argmax(logits_pf[:, :, :cfg.vocab_size], -1).astype(jnp.int32)
    l1, _ = model.decode_step(params, cache_pf, nxt,
                              jnp.asarray(S, jnp.int32))
    l2, _ = model.decode_step(params, cache, nxt,
                              jnp.asarray(S, jnp.int32))
    assert float(jnp.max(jnp.abs(
        jax.nn.log_softmax(l1[:, 0].astype(jnp.float32))
        - jax.nn.log_softmax(l2[:, 0].astype(jnp.float32))))) < 5e-2
