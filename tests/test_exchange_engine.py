"""Bandwidth-optimal exchange engine (ISSUE 5 / DESIGN.md §11).

Acceptance-critical invariants:
  * the fused codec-mix epilogue (kernels/exchange_epilogue.py) is
    BIT-identical to the staged reference path for int8/fp16/bf16 x
    server/ring/gossip x jnp/pallas, and Exchange.streams routes the
    flat-buffer hot path through it by default,
  * the ppermute neighbor hop is bit-exact vs the all_gather hop (same
    assembled rows, same W-row contraction) while shipping only
    O(deg·shard) wire (neighbor_offsets / edge-true accounting),
  * sharded top-k (distributed threshold selection + shard-local EF
    residual) selects at most k entries, never the zero pad, keeps the
    EF identity exactly, and convergence-matches the replicated exact
    selection,
  * the downlink codec compresses the broadcast reply independently of
    the uplink with its own state + wire accounting; the default stays
    bit-exact with the pre-§11 rounds,
  * property-style pad invariants: the zero-pad tail of a ShardedLayout
    is a fixed point of the ppermute hop, the fused epilogue, and the
    sharded top-k selection,
  * the billion-param packed guard refuses int32-overflowing layouts
    with the limit stated (launch/dryrun satellite).

8-device tests ride the same forced-host child-process pattern as
tests/test_shardexec.py (REPRO_SHARDEXEC_CHILD gates the in-suite
driver so CI's dedicated 8-device job doesn't pay twice).
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import comm, optim
from repro.comm import topology as topo
from repro.core import localsgd as lsgd
from repro.kernels import exchange_epilogue as ee
from repro.optim import packing
from repro.sharding import shardexec as shx

HAVE8 = jax.device_count() >= 8
needs8 = pytest.mark.skipif(not HAVE8, reason="needs 8 devices "
                            "(forced-host child process runs these)")

G = 4


def quad_loss(params, batch):
    r = batch["A"] @ params["w"] - batch["b"]
    return 0.5 * jnp.sum(r ** 2)


def make_problem(key, g=G, r=8, d=40):
    ks = jax.random.split(key, 3)
    A = jax.random.normal(ks[0], (g, r, d)) / np.sqrt(d)
    w_star = jax.random.normal(ks[1], (d,))
    batch = {"A": A, "b": jnp.einsum("grd,d->gr", A, w_star)}
    params = {"w": jax.random.normal(ks[2], (d,))}
    return params, batch


def mesh8(shape=(4, 2), axes=("data", "model")):
    from jax.sharding import Mesh
    n = int(np.prod(shape))
    return Mesh(np.array(jax.devices()[:n]).reshape(shape), axes)


# ---------------------------------------------------------------------------
# topology: offset decomposition (no devices needed)
# ---------------------------------------------------------------------------


def test_neighbor_offsets_ring_is_edge_true():
    """A ring's off-diagonal support is exactly the offsets {1, m-1}, so
    the ppermute hop ships n_edge_sends payloads — edge-true wire."""
    for m in (4, 8, 16):
        w = topo.ring_matrix(m)
        offs = topo.neighbor_offsets(w)
        assert offs == (1, m - 1), (m, offs)
        assert topo.n_edge_sends(w) == 2 * m == len(offs) * m
        ow = topo.offset_weights(w, offs)
        assert ow.shape == (2, m)
        np.testing.assert_allclose(ow, 1.0 / 3.0)


def test_neighbor_offsets_gossip_covers_support():
    """Every nonzero W[i,j] is reachable at one of the offsets, and the
    offset weights reproduce W's off-diagonal row entries."""
    w = topo.gossip_matrix(8, seed=3)
    offs = topo.neighbor_offsets(w)
    ow = topo.offset_weights(w, offs)
    got = np.zeros_like(w)
    g = np.arange(8)
    for di, d in enumerate(offs):
        got[g, (g + d) % 8] = ow[di]
    off = w.copy()
    np.fill_diagonal(off, 0.0)
    np.testing.assert_allclose(got, off, atol=1e-12)
    # the union-of-offsets ship count upper-bounds the edge-true count
    assert topo.n_edge_sends(w) <= len(offs) * 8


# ---------------------------------------------------------------------------
# fused codec-mix epilogue: bit-identity + pad fixed point (replicated)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topology", ["server", "ring", "gossip"])
@pytest.mark.parametrize("codec", ["int8", "bf16", "fp16"])
def test_fused_stream_bit_identical_to_staged(topology, codec, key):
    """THE §11 fused-epilogue gate: Exchange.streams with the fused
    codec-mix epilogue (default) is BIT-identical to the staged
    reference path (fused=False), including the codec state counter."""
    mr = 1 if topology == "server" else 3
    ex = comm.get_exchange(topology, codec, G, mix_rounds=mr, impl="jnp")
    staged = dataclasses.replace(ex, fused=False)
    x0 = jax.random.normal(key, (G, 700))
    x = x0 + jax.random.normal(jax.random.fold_in(key, 1), x0.shape) * 0.1
    st = ex.init(x0)
    out_f, st_f = jax.jit(ex.params)(x, x0, st)
    out_s, st_s = jax.jit(staged.params)(x, x0, st)
    np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out_s))
    if codec == "int8":
        assert int(st_f["codec"]["params"]["count"]) \
            == int(st_s["codec"]["params"]["count"]) == mr


def test_fused_epilogue_pallas_bit_identical_to_jnp(key):
    """The Pallas kernel (interpret mode on CPU) and the jnp reference
    consume the same inputs and agree exactly — including the fused qdq
    kernel the int8 codec's pallas impl now routes through."""
    x0 = jax.random.normal(key, (G, 700))
    x = x0 + jax.random.normal(jax.random.fold_in(key, 1), x0.shape) * 0.1
    for topology, mr in (("server", 1), ("ring", 2)):
        ex_p = comm.get_exchange(topology, "int8", G, mix_rounds=mr,
                                 impl="pallas")
        ex_j = comm.get_exchange(topology, "int8", G, mix_rounds=mr,
                                 impl="jnp")
        st = ex_p.init(x0)
        op, _ = jax.jit(ex_p.params)(x, x0, st)
        oj, _ = jax.jit(ex_j.params)(x, x0, st)
        np.testing.assert_array_equal(np.asarray(op), np.asarray(oj))
    # qdq_int8 == quantize_int8 + dequantize_int8, bit for bit
    from repro.kernels.quantize import dequantize_int8, quantize_int8
    rows = jax.random.normal(key, (6, 256))
    u = jax.random.uniform(jax.random.fold_in(key, 2), rows.shape)
    fused = ee.qdq_int8(rows, u, interpret=True)
    q, s = quantize_int8(rows, u, interpret=True)
    np.testing.assert_array_equal(np.asarray(fused),
                                  np.asarray(dequantize_int8(
                                      q, s, interpret=True)))


@pytest.mark.parametrize("kind", ["int8", "bf16", "thresh"])
@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_fused_epilogue_pad_is_fixed_point(kind, impl, key):
    """Property (ISSUE 5 satellite): a zero tail (the ShardedLayout pad)
    stays exactly zero through the fused epilogue — zero chunks quantize
    to zero, casts keep zero, thresh never selects |c| = 0 — and the
    thresh residual stays zero in the pad."""
    n_real, pad = 300, 212
    n = n_real + pad
    mask = (jnp.arange(n) < n_real).astype(jnp.float32)
    x0 = jax.random.normal(key, (G, n)) * mask
    x = x0 + jax.random.normal(jax.random.fold_in(key, 1),
                               (G, n)) * 0.1 * mask
    kw = dict(kind=kind, impl=impl, interpret=True)
    if kind == "int8":
        chunk = 256
        rows = (G * ((n + chunk - 1) // chunk), chunk)
        c = comm.get_codec("int8", impl="jnp")
        kw.update(chunk=chunk, u=c.noise(jnp.zeros((), jnp.int32), rows)
                  [None])
    if kind == "thresh":
        kw.update(residual=jnp.zeros_like(x),
                  tau=jnp.full((G, 1), 0.05, jnp.float32))
    mixed, res = ee.codec_mix(x, x0, **kw)
    np.testing.assert_array_equal(np.asarray(mixed[:, n_real:]), 0.0)
    if res is not None:
        np.testing.assert_array_equal(np.asarray(res[:, n_real:]), 0.0)


def test_fused_server_topk_stream_matches_staged(key):
    """Server top-k routes through the fused thresh epilogue by default
    (DESIGN.md §11): multi-round Exchange.streams — residual threading
    included — matches the staged exact-selection path bit for bit on
    tie-free data, for both kernel impls."""
    for impl in ("jnp", "pallas"):
        ex = comm.get_exchange("server", "topk", G, topk_frac=0.1,
                               impl=impl)
        assert ex.codec.impl == impl
        staged = dataclasses.replace(ex, fused=False)
        x0 = jax.random.normal(key, (G, 300))
        st_f, st_s = ex.init(x0), ex.init(x0)
        for i in range(3):
            x = x0 + jax.random.normal(jax.random.fold_in(key, i),
                                       x0.shape) * 0.1
            out_f, st_f = jax.jit(ex.params)(x, x0, st_f)
            out_s, st_s = jax.jit(staged.params)(x, x0, st_s)
            np.testing.assert_array_equal(np.asarray(out_f),
                                          np.asarray(out_s))
            np.testing.assert_array_equal(
                np.asarray(st_f["codec"]["params"]["residual"]),
                np.asarray(st_s["codec"]["params"]["residual"]))
            x0 = out_f
    # ring top-k keeps the staged per-hop path (no thresh fusion there)
    ex_r = comm.get_exchange("ring", "topk", G, mix_rounds=2)
    assert not ex_r._fusable(ex_r.codec, jnp.zeros((G, 8)))


def test_fused_thresh_matches_exact_topk_without_ties(key):
    """With tau = the exact k-th |c| (no ties in generic data), the
    fused thresh epilogue reproduces the staged exact-top-k server
    exchange bit for bit."""
    frac = 0.1
    n = 512
    ex = dataclasses.replace(
        comm.get_exchange("server", "topk", G, topk_frac=frac),
        fused=False)   # the STAGED exact-selection reference
    x0 = jax.random.normal(key, (G, n))
    x = x0 + jax.random.normal(jax.random.fold_in(key, 1), x0.shape) * 0.1
    st = ex.init(x0)
    out_staged, st_staged = jax.jit(ex.params)(x, x0, st)
    k = max(1, round(frac * n))
    c = x - x0   # residual starts zero
    tau = jax.lax.top_k(jnp.abs(c), k)[0][:, -1:]
    mixed, res = ee.codec_mix(x, x0, kind="thresh", residual=jnp.zeros_like(c),
                              tau=tau, impl="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(mixed), np.asarray(out_staged))
    np.testing.assert_array_equal(
        np.asarray(res), np.asarray(st_staged["codec"]["params"]["residual"]))


# ---------------------------------------------------------------------------
# downlink codec (replicated path)
# ---------------------------------------------------------------------------


def test_downlink_default_and_fp32_bit_exact(key):
    """No downlink codec (default) and an explicit fp32 downlink are both
    bit-exact with the pre-§11 exchange — the knob only changes the
    accounting width in the fp32 case."""
    x0 = jax.random.normal(key, (G, 300))
    x = x0 + jax.random.normal(jax.random.fold_in(key, 1), x0.shape) * 0.1
    base = comm.get_exchange("server", "int8", G, impl="jnp")
    dl32 = comm.get_exchange("server", "int8", G, impl="jnp",
                             downlink_codec="fp32")
    st = base.init(x0)
    ob, _ = jax.jit(base.params)(x, x0, st)
    o32, _ = jax.jit(dl32.params)(x, x0, dl32.init(x0))
    np.testing.assert_array_equal(np.asarray(ob), np.asarray(o32))
    # accounting: default prices the downlink at the uplink width;
    # fp32 downlink prices it at 4 bytes/elem
    n = 300
    assert base.wire_bytes_down(n) == G * base.codec.wire_bytes(n)
    assert dl32.wire_bytes_down(n) == G * 4 * n
    assert base.wire_bytes_up(n) == dl32.wire_bytes_up(n)


def test_downlink_codec_noise_and_state(key):
    """A lossy downlink injects bounded broadcast noise, keeps its own
    per-stream reference + codec state under comm["down"], and its
    delta coding makes the noise vanish as the mean converges."""
    x0 = jax.random.normal(key, (G, 300))
    ex = comm.get_exchange("server", "fp32", G, downlink_codec="int8",
                           impl="jnp")
    assert ex.stateful and ex.name == "server/fp32+d:int8"
    st = ex.init(x0)
    assert set(st["down"]) == {"params"}
    x = x0 + jax.random.normal(jax.random.fold_in(key, 1), x0.shape) * 0.1
    out, st = jax.jit(ex.params)(x, x0, st)
    want = jnp.broadcast_to(jnp.mean(x, 0, keepdims=True), x.shape)
    err0 = float(jnp.max(jnp.abs(out - want)))
    assert 0 < err0 < 0.05
    # every group receives the SAME decoded broadcast
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out[1]))
    assert int(st["down"]["params"]["state"]["count"]) == 1
    # re-broadcasting an unchanged mean: the delta vs the stored ref
    # shrinks, so the decode error shrinks with it
    out2, st = jax.jit(ex.params)(x, x0, st)
    err1 = float(jnp.max(jnp.abs(out2 - want)))
    assert err1 <= err0 + 1e-7


def test_downlink_round_level_accounting_and_clamp(key):
    """A packed adamw round with an int8 downlink: wire_bytes_down in
    the metrics matches the static accounting at the DOWNLINK width, the
    down state threads through the train state, and the non-negative
    moment projection also covers downlink-noised v."""
    params, batch = make_problem(key)
    layout = packing.layout_of(params)
    opt = optim.packed("adamw", 0.02, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)
    ex = comm.get_exchange("server", "fp32", G, downlink_codec="int8")
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg,
                                        layout=layout, exchange=ex))
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                         exchange=ex)
    assert set(st["comm"]["down"]) == {"params", "m", "v"}
    for _ in range(3):
        st, m = rnd(st, batch)
    n = layout.padded
    sizes = {k: n for k in opt.moment_keys}
    assert int(m["wire_bytes_down"]) == ex.wire_bytes_down(
        n, moment_sizes=sizes)
    assert int(m["wire_bytes_up"]) == ex.wire_bytes_up(
        n, moment_sizes=sizes)
    assert int(m["wire_bytes"]) == ex.wire_bytes_per_round(
        n, moment_sizes=sizes)
    # int8 downlink (1B + scales) is cheaper than the fp32 uplink here
    assert m["wire_bytes_down"] < m["wire_bytes_up"]
    # v came through a lossy broadcast: the clamp kept it non-negative
    assert float(jnp.min(st["opt"]["v"])) >= 0.0


def test_downlink_refusals():
    for topo_ in ("ring", "gossip", "none"):
        with pytest.raises(NotImplementedError):
            comm.get_exchange(topo_, "fp32", G, downlink_codec="int8")
    with pytest.raises(NotImplementedError):
        comm.get_exchange("server", "fp32", G, downlink_codec="topk")
    # flat-only downlink needs the packed wire format
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)
    with pytest.raises(NotImplementedError):
        lsgd.make_local_round(
            quad_loss, optim.sgd(0.1), cfg,
            exchange=comm.get_exchange("server", "fp32", G,
                                       downlink_codec="int8"))


def test_downlink_checkpoint_roundtrip(key, tmp_path):
    """The nested down state (per-stream ref + codec counter) survives a
    checkpoint round trip bit-exactly (same contract as §10 states)."""
    from repro.checkpoint import io as ckpt_io

    params, batch = make_problem(key)
    layout = packing.layout_of(params)
    opt = optim.packed("momentum", 0.05, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)
    ex = comm.get_exchange("server", "int8", G, downlink_codec="bf16")
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg,
                                        layout=layout, exchange=ex))
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                         exchange=ex)
    st, _ = rnd(st, batch)
    path = str(tmp_path / "ck")
    ckpt_io.save(path, st, metadata={})
    back = ckpt_io.load(path, st)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    st2, _ = rnd(back, batch)
    stc, _ = rnd(st, batch)
    for a, b in zip(jax.tree.leaves(st2), jax.tree.leaves(stc)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# billion-param packed guard (launch/dryrun satellite)
# ---------------------------------------------------------------------------


def test_packed_index_space_guard():
    """The billion-param packed dryrun used to die mid-lower with a bare
    int32 OverflowError (PR 3 note); now the layout math refuses up
    front with the limit stated."""
    big = packing.Layout(treedef=None, shapes=((10**9,),),
                         dtypes=(jnp.float32,), offsets=(0,),
                         sizes=(10**9,), size=10**9)
    packing.check_packed_index_space(big, 2)          # 2e9 < 2^31-1: ok
    with pytest.raises(NotImplementedError, match="2\\*\\*31-1"):
        packing.check_packed_index_space(big, 3)      # 3e9: refused
    huge = dataclasses.replace(big, shapes=((3 * 10**9,),),
                               sizes=(3 * 10**9,), size=3 * 10**9)
    with pytest.raises(NotImplementedError):
        packing.check_packed_index_space(huge)
    # the packed round builder hits the guard before any tracing
    cfg = lsgd.LocalSGDConfig(n_groups=3, inner_steps=1)
    opt = optim.packed("sgd", 0.1, impl="jnp")
    with pytest.raises(NotImplementedError, match="int32 index space"):
        lsgd.make_local_round(quad_loss, opt, cfg, layout=big)
    with pytest.raises(NotImplementedError):
        lsgd.make_sync_step(quad_loss, opt, layout=huge)


# ---------------------------------------------------------------------------
# 8-device mesh: ppermute parity, sharded top-k
# ---------------------------------------------------------------------------


@needs8
@pytest.mark.parametrize("topology", ["ring", "gossip"])
def test_ppermute_hop_bit_exact_vs_allgather(topology, key):
    """THE §11 hop gate: the ppermute neighbor hop assembles the same
    (G, shard) rows the all_gather produced (absent neighbors zero) and
    contracts with the same W row — codec-free mixing AND the full int8
    multi-stream exchange are bit-exact between the two hop impls, and
    both match the replicated path."""
    mesh = mesh8()
    sexec = shx.plan_for(mesh)
    assert sexec.hop_impl == "ppermute"
    sexec_ag = dataclasses.replace(sexec, hop_impl="allgather")
    params, _ = make_problem(key)
    layout = packing.shard_layout(packing.layout_of(params),
                                  sexec.n_shards)
    x0 = packing.pack(lsgd.replicate(params, G), layout)
    mask = (jnp.arange(layout.padded) < layout.size).astype(jnp.float32)
    x = x0 + jax.random.normal(jax.random.fold_in(key, 1),
                               x0.shape) * 0.1 * mask
    ex = comm.get_exchange(topology, "fp32", G, mix_rounds=3)
    mp = jax.jit(sexec.mix(ex))(x)
    ma = jax.jit(sexec_ag.mix(ex))(x)
    np.testing.assert_array_equal(np.asarray(mp), np.asarray(ma))
    # and <= 1e-5 vs the replicated mixing (reduction-order only)
    np.testing.assert_allclose(np.asarray(mp), np.asarray(ex.mix(x)),
                               rtol=1e-5, atol=1e-6)
    ex8 = comm.get_exchange(topology, "int8", G, mix_rounds=2, impl="jnp",
                            moment_codec="int8")
    moments = {"mu": x * 0.5}
    st = ex8.init(x0, moments=moments)
    fp = jax.jit(sexec.exchange_streams(ex8, layout))
    fa = jax.jit(sexec_ag.exchange_streams(ex8, layout))
    xs = {"params": x, "mu": x * 0.5}
    xs0 = {"params": x0, "mu": x0 * 0.5}
    op, sp = fp(xs, xs0, st)
    oa, sa = fa(xs, xs0, st)
    for k in xs:
        np.testing.assert_array_equal(np.asarray(op[k]), np.asarray(oa[k]))
    orr, _ = jax.jit(ex8.streams)(xs, xs0, st)
    for k in xs:
        np.testing.assert_allclose(np.asarray(op[k]), np.asarray(orr[k]),
                                   rtol=1e-5, atol=1e-6)


@needs8
def test_ppermute_pad_is_fixed_point(key):
    """Property (ISSUE 5 satellite): the zero-pad tail stays exactly
    zero through ppermute hops (a convex combination of zeros)."""
    mesh = mesh8()
    sexec = shx.plan_for(mesh)
    params, _ = make_problem(key)
    layout = packing.shard_layout(packing.layout_of(params),
                                  sexec.n_shards)
    x0 = packing.pack(lsgd.replicate(params, G), layout)
    mask = (jnp.arange(layout.padded) < layout.size).astype(jnp.float32)
    x = x0 + jax.random.normal(key, x0.shape) * mask
    assert layout.padded > layout.size   # there IS a pad to check
    for topology in ("ring", "gossip"):
        ex = comm.get_exchange(topology, "fp32", G, mix_rounds=4)
        out = np.asarray(jax.jit(sexec.mix(ex))(x))
        np.testing.assert_array_equal(out[:, layout.size:], 0.0)


@needs8
def test_sharded_topk_selection_properties(key):
    """Sharded top-k (DESIGN.md §11): at most k entries selected per
    group, the zero pad is NEVER selected, the shard-local residual
    keeps the EF identity exactly and stays zero in the pad."""
    mesh = mesh8()
    sexec = shx.plan_for(mesh)
    params, _ = make_problem(key)
    layout = packing.shard_layout(packing.layout_of(params),
                                  sexec.n_shards)
    x0 = packing.pack(lsgd.replicate(params, G), layout)
    mask = (jnp.arange(layout.padded) < layout.size).astype(jnp.float32)
    x = x0 + jax.random.normal(jax.random.fold_in(key, 1),
                               x0.shape) * 0.1 * mask
    frac = 0.02
    ex = comm.get_exchange("server", "topk", G, topk_frac=frac)
    k = max(1, round(frac * layout.padded))
    assert k < layout.size   # a real selection, not select-everything
    out, st = jax.jit(sexec.exchange(ex, layout))(x, x0, ex.init(x0))
    res = np.asarray(st["codec"]["params"]["residual"])
    c = np.asarray(x - x0)
    d_hat = c - res          # EF identity: c == d_hat + residual exactly
    nsel = (d_hat != 0).sum(axis=1)
    assert (nsel <= k).all(), (nsel, k)
    assert (nsel >= 1).all()
    np.testing.assert_array_equal(d_hat[:, layout.size:], 0.0)
    np.testing.assert_array_equal(res[:, layout.size:], 0.0)
    # every shipped entry beats every kept entry (threshold selection)
    for g in range(G):
        shipped = np.abs(d_hat[g][d_hat[g] != 0])
        kept = np.abs(res[g][(d_hat[g] == 0) & (c[g] != 0)])
        if shipped.size and kept.size:
            assert shipped.min() >= kept.max() - 1e-12


@needs8
def test_sharded_topk_matches_replicated_convergence(key):
    """The §11 convergence gate at test scale: multi-round packed topk
    rounds — sharded (distributed threshold) vs replicated (exact
    selection) — converge to the same feasibility point; the selection
    deviation only re-orders WHEN near-threshold mass ships."""
    mesh = mesh8()
    sexec = shx.plan_for(mesh)
    params, batch = make_problem(key, r=24, d=32)
    layout = packing.shard_layout(packing.layout_of(params),
                                  sexec.n_shards)
    ex = comm.get_exchange("server", "topk", G, topk_frac=0.05)
    opt_s = optim.get("sgd", 0.4, packed=True, impl="pallas")
    opt_r = optim.get("sgd", 0.4, packed=True, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=4)
    rnd_s = jax.jit(lsgd.make_local_round(quad_loss, opt_s, cfg,
                                          layout=layout, exchange=ex,
                                          shardexec=sexec))
    rnd_r = jax.jit(lsgd.make_local_round(quad_loss, opt_r, cfg,
                                          layout=layout, exchange=ex))
    ss = lsgd.init_state(params, opt_s, n_groups=G, layout=layout,
                         exchange=ex)
    sr = lsgd.init_state(params, opt_r, n_groups=G, layout=layout,
                         exchange=ex)
    for _ in range(80):
        ss, ms = rnd_s(ss, batch)
        sr, mr = rnd_r(sr, batch)
    gs, gr = float(jnp.mean(ms["grad_sq"])), float(jnp.mean(mr["grad_sq"]))
    assert gs < 1e-10 and gr < 1e-10, (gs, gr)
    assert gs <= 10 * gr + 1e-12, (gs, gr)
    # the residual stayed shard-pure zero in the pad all along
    res = np.asarray(ss["comm"]["codec"]["params"]["residual"])
    np.testing.assert_array_equal(res[:, layout.size:], 0.0)


@needs8
def test_sharded_topk_ring_runs_and_contracts(key):
    """Per-hop sharded top-k on a ring: finite, contracts disagreement
    (spectral gap survives the threshold codec), residual pad clean."""
    mesh = mesh8()
    sexec = shx.plan_for(mesh)
    params, _ = make_problem(key)
    layout = packing.shard_layout(packing.layout_of(params),
                                  sexec.n_shards)
    x0 = packing.pack(lsgd.replicate(params, G), layout)
    mask = (jnp.arange(layout.padded) < layout.size).astype(jnp.float32)
    x = x0 + jax.random.normal(key, x0.shape) * mask
    ex = comm.get_exchange("ring", "topk", G, mix_rounds=4,
                           topk_frac=0.25)
    out, st = jax.jit(sexec.exchange(ex, layout))(x, x0, ex.init(x0))
    o = np.asarray(out)
    assert np.isfinite(o).all()
    dis_in = float(np.abs(np.asarray(x) - np.asarray(x).mean(0)).max())
    dis_out = float(np.abs(o - o.mean(0)).max())
    assert dis_out < 0.9 * dis_in
    np.testing.assert_array_equal(
        np.asarray(st["codec"]["params"]["residual"])[:, layout.size:], 0.0)


@needs8
def test_builder_threads_topk_sharded(key):
    """The mesh builder accepts codec=topk on a sharded mesh now (the
    §9 refusal is lifted) and the comm state carries the sharded
    residual with the buffer's spec."""
    from repro.configs.base import InputShape, get_config
    from repro.launch.steps import build_train_step

    cfg = get_config("paper-mlp").reduced()
    mesh = mesh8()
    shape = InputShape(name="tiny", kind="train", global_batch=8,
                       seq_len=8)
    built = build_train_step(cfg, shape, mesh, t_inner=2, packed=True,
                             codec="topk", impl="pallas")
    assert built.meta["sharded"] is True
    state_abs, _ = built.args
    r = state_abs["comm"]["codec"]["params"]["residual"]
    assert r.shape == state_abs["params"].shape
    # the EF residual SHARDS like the params (a lead-only spec would
    # reshard the O(Np) residual through every round's shard_map call)
    psh = built.in_shardings[0]["params"]
    rsh = built.in_shardings[0]["comm"]["codec"]["params"]["residual"]
    assert rsh.shard_shape(tuple(r.shape)) \
        == psh.shard_shape(tuple(state_abs["params"].shape))
    with mesh:
        jitted = jax.jit(built.fn, in_shardings=built.in_shardings,
                         out_shardings=built.out_shardings,
                         donate_argnums=built.donate_argnums)
        jitted.lower(*built.args).compile()


# ---------------------------------------------------------------------------
# tier-1 driver: force 8 host devices in a child process
# ---------------------------------------------------------------------------


def test_suite_under_forced_8_devices():
    """Under the plain 1-device tier-1 run, re-run this module with 8
    forced host devices in a subprocess (jax locks the device count at
    first init). CI's forced-8-device job runs the tests directly and
    skips this driver (REPRO_SHARDEXEC_CHILD, shared with
    test_shardexec.py)."""
    if HAVE8:
        pytest.skip("already running with 8 devices")
    if os.environ.get("REPRO_SHARDEXEC_CHILD") == "1":
        pytest.skip("child process")
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                        + env.get("XLA_FLAGS", "")).strip()
    env["REPRO_SHARDEXEC_CHILD"] = "1"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(repo, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         os.path.abspath(__file__)],
        env=env, capture_output=True, text=True, timeout=1800,
        cwd=repo)
    assert r.returncode == 0, (
        f"8-device exchange-engine suite failed:\n{r.stdout[-4000:]}"
        f"\n{r.stderr[-2000:]}")
