"""Optimizer substrate: base optimizers, clipping, schedules, and the
paper's heterogeneous per-node T_i."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import localsgd as lsgd


def quad_loss(params, batch):
    r = batch["A"] @ params["w"] - batch["b"]
    return 0.5 * jnp.sum(r ** 2)


def make_batch(key, G, r, d):
    ks = jax.random.split(key, 2)
    A = jax.random.normal(ks[0], (G, r, d)) / np.sqrt(d)
    w_star = jax.random.normal(ks[1], (d,))
    return {"A": A, "b": jnp.einsum("grd,d->gr", A, w_star)}


@pytest.mark.parametrize("name", ["sgd", "momentum", "adamw"])
def test_optimizers_descend(name, key):
    opt = optim.get(name, 0.05)
    w = jax.random.normal(key, (8,))
    batch = {"A": jnp.eye(8), "b": jnp.zeros(8)}
    state = opt.init({"w": w})
    params = {"w": w}
    l0 = quad_loss(params, batch)
    for _ in range(20):
        loss, g = jax.value_and_grad(quad_loss)(params, batch)
        params, state = opt.step(params, g, state)
    assert quad_loss(params, batch) < 0.5 * l0


def test_clip_by_global_norm(key):
    opt = optim.clip_by_global_norm(optim.sgd(1.0), max_norm=1.0)
    params = {"w": jnp.zeros(4)}
    g = {"w": jnp.full((4,), 100.0)}  # norm 200
    new, _ = opt.step(params, g, opt.init(params))
    # update magnitude == lr * clipped norm == 1.0
    assert abs(float(jnp.linalg.norm(new["w"])) - 1.0) < 1e-5
    # small grads pass through unclipped
    g2 = {"w": jnp.full((4,), 0.1)}
    new2, _ = opt.step(params, g2, opt.init(params))
    np.testing.assert_allclose(new2["w"], -0.1 * jnp.ones(4), rtol=1e-6)


def test_cosine_schedule_shape():
    lr_fn = optim.cosine_schedule(1.0, warmup=10, total=100, min_frac=0.1)
    lrs = [float(lr_fn(c)) for c in range(0, 100, 5)]
    assert lrs[0] < lrs[1]                 # warmup rising
    assert max(lrs) <= 1.0 + 1e-6
    assert abs(float(lr_fn(99)) - 0.1) < 0.02   # decayed to min_frac


def test_with_schedule_matches_manual(key):
    lr_fn = optim.cosine_schedule(0.1, warmup=2, total=20)
    opt = optim.with_schedule(optim.sgd, lr_fn)
    params = {"w": jnp.ones(4)}
    state = opt.init(params)
    g = {"w": jnp.ones(4)}
    new, state = opt.step(params, g, state)
    want = 1.0 - float(lr_fn(0))
    np.testing.assert_allclose(new["w"], want, rtol=1e-6)


def test_heterogeneous_t_i(key):
    """Paper Alg 1 with different T_i per worker: a group with T_i=0-ish
    (1 step) must move less than a group with T_i=8; averaging still
    produces identical replicas."""
    G, r, d = 3, 4, 6
    batch = make_batch(key, G, r, d)
    w0 = jax.random.normal(jax.random.PRNGKey(1), (d,))
    opt = optim.sgd(0.05)
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=8, t_i=(1, 4, 8))
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg))
    state = lsgd.init_state({"w": w0}, opt, n_groups=G)
    new_state, m = rnd(state, batch)
    assert list(np.asarray(m["inner_steps"])) == [1, 4, 8]
    # replicas identical after averaging
    np.testing.assert_allclose(new_state["params"]["w"][0],
                               new_state["params"]["w"][-1], rtol=1e-6)


def test_heterogeneous_t_i_matches_manual(key):
    G, r, d, lr = 2, 3, 5, 0.1
    batch = make_batch(key, G, r, d)
    w0 = jax.random.normal(jax.random.PRNGKey(2), (d,))
    opt = optim.sgd(lr)
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=6, t_i=(2, 6))
    rnd = lsgd.make_local_round(quad_loss, opt, cfg)
    state = lsgd.init_state({"w": w0}, opt, n_groups=G)
    new_state, _ = rnd(state, batch)

    A = np.asarray(batch["A"]); b = np.asarray(batch["b"])
    ws = []
    for i, T in enumerate((2, 6)):
        w = np.asarray(w0, np.float64)
        for _ in range(T):
            w = w - lr * (A[i].T @ (A[i] @ w - b[i]))
        ws.append(w)
    np.testing.assert_allclose(new_state["params"]["w"][0],
                               np.mean(ws, 0), rtol=1e-5, atol=1e-6)
