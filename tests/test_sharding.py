"""Divisibility-aware PartitionSpec resolution + batch/cache specs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import get_config
from repro.launch.mesh import make_local_mesh
from repro.models import attention as attn
from repro.models import build_model
from repro.models.layers import pdef
from repro.sharding import specs as sh


def mesh1():
    return make_local_mesh(1, 1)


class FakeMesh:
    """Mesh-shaped stand-in with arbitrary axis sizes (no devices needed
    for pure spec resolution)."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


def test_divisible_axis_sharded():
    m = FakeMesh(data=16, model=16)
    d = pdef((1024, 6400), ("embed", "ff"))
    assert sh.spec_for(d, m) == P(None, "model")


def test_indivisible_axis_replicated():
    m = FakeMesh(data=16, model=16)
    # internvl2: 14 heads don't divide 16
    d = pdef((896, 14, 64), ("embed", "heads", None))
    assert sh.spec_for(d, m) == P()


def test_first_divisible_rule():
    m = FakeMesh(data=16, model=16)
    # both vocab and embed-ff shardable: only the first gets the axis
    d = pdef((128512, 4096), ("vocab", "ff"))
    assert sh.spec_for(d, m) == P("model")


def test_leading_group_axis_single_pod():
    m = FakeMesh(data=16, model=16)
    d = pdef((1024, 512), ("embed", "ff"))
    assert sh.spec_for(d, m, leading=("data",)) == P("data", None, "model")


def test_leading_group_axis_multi_pod():
    m = FakeMesh(pod=2, data=16, model=16)
    d = pdef((1024, 512), ("embed", "ff"))
    got = sh.spec_for(d, m, leading=("pod", "data"))
    assert got == P(("pod", "data"), None, "model")


def test_dp_axes_and_groups():
    assert sh.dp_axes(FakeMesh(data=16, model=16)) == ("data",)
    assert sh.dp_axes(FakeMesh(pod=2, data=16, model=16)) == ("pod", "data")
    assert sh.n_groups(FakeMesh(pod=2, data=16, model=16)) == 32


def test_batch_spec():
    m = FakeMesh(pod=2, data=16, model=16)
    assert sh.batch_spec(m, 256, False) == P(("pod", "data"))
    assert sh.batch_spec(m, 1, False) == P()      # indivisible -> replicated
    assert sh.batch_spec(m, 30, False) == P()
    assert sh.batch_spec(m, 64, True) == P(("pod", "data"))


@pytest.mark.parametrize("arch", ["qwen3-32b", "granite-moe-1b-a400m",
                                  "xlstm-1.3b", "zamba2-7b"])
def test_specs_are_placeable(arch):
    """Every resolved spec must be applicable to its param's actual shape
    (rank & divisibility) on a real 1x1 mesh."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    mesh = mesh1()
    pspecs = sh.resolve_specs(model.defs, mesh)
    abs_p = model.abstract()

    def check(s, a):
        assert isinstance(s, P)
        assert len(s) <= len(a.shape), (s, a.shape)
        NamedSharding(mesh, s).shard_shape(a.shape)  # raises if invalid

    jax.tree.map(check, pspecs, abs_p,
                 is_leaf=lambda x: isinstance(x, P))


def test_full_config_specs_shard_big_dims():
    """On a (fake) 16x16 mesh the big tensors of qwen3-32b must shard."""
    m = FakeMesh(data=16, model=16)
    cfg = get_config("qwen3-32b")
    d = attn.attention_defs(cfg)
    # trailing Nones are stripped by spec_for
    assert sh.spec_for(d["wq"], m) == P(None, "model")  # 64 heads /16
    assert sh.spec_for(d["wk"], m) == P()               # 8 kv heads
    from repro.models.mlp import mlp_defs
    md = mlp_defs(cfg)
    for k in md:
        s = sh.spec_for(md[k], m)
        assert "model" in jax.tree.leaves(tuple(s)), (k, s)
