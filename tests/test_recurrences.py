"""Deep equivalence tests for the recurrent stacks: the chunked (parallel,
MXU-friendly) forward must agree with the token-by-token recurrent decode
on the SAME parameters — this is the correctness backbone of the zamba2 /
xlstm long_500k serving path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import mamba as mam
from repro.models import xlstm as xl
from repro.models.layers import init_params


@pytest.mark.parametrize("chunk", [4, 8])
def test_mamba_chunked_equals_recurrent(chunk, key):
    cfg = dataclasses.replace(get_config("zamba2-7b").reduced(),
                              chunk_size=chunk, dtype="float32")
    defs = mam.mamba_defs(cfg)
    p = init_params(defs, key)
    B, S = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.5

    y_par = mam.mamba_forward(p, x, cfg)

    cache = mam.init_mamba_cache(cfg, B, jnp.float32)
    ys = []
    for t in range(S):
        y_t, cache = mam.mamba_decode(p, x[:, t:t + 1], cfg, cache)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_par, y_seq, atol=5e-4, rtol=5e-3)


@pytest.mark.parametrize("chunk", [4, 8])
def test_mlstm_chunked_equals_recurrent(chunk, key):
    cfg = dataclasses.replace(get_config("xlstm-1.3b").reduced(),
                              chunk_size=chunk, dtype="float32")
    defs = xl.mlstm_defs(cfg)
    p = init_params(defs, key)
    B, S = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model)) * 0.5

    y_par = xl.mlstm_forward(p, x, cfg)

    cache = xl.init_mlstm_cache(cfg, B, jnp.float32)
    ys = []
    for t in range(S):
        y_t, cache = xl.mlstm_decode(p, x[:, t:t + 1], cfg, cache)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_par, y_seq, atol=5e-4, rtol=5e-3)


def test_slstm_scan_equals_stepwise(key):
    cfg = dataclasses.replace(get_config("xlstm-1.3b").reduced(),
                              dtype="float32")
    defs = xl.slstm_defs(cfg)
    p = init_params(defs, key)
    B, S = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(3), (B, S, cfg.d_model)) * 0.5

    y_par = xl.slstm_forward(p, x, cfg)

    cache = xl.init_slstm_cache(cfg, B, jnp.float32)
    ys = []
    for t in range(S):
        y_t, cache = xl.slstm_decode(p, x[:, t:t + 1], cfg, cache)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_par, y_seq, atol=1e-4, rtol=1e-3)


def test_mamba_state_matches_kernel_state(key):
    """The model-level chunked state scan and the Pallas kernel's
    summarized per-chunk state must be the same quantity."""
    from repro.kernels import ref

    cfg = dataclasses.replace(get_config("zamba2-7b").reduced(),
                              chunk_size=8, dtype="float32")
    di, H, P, N = mam.mamba_dims(cfg)
    L = cfg.chunk_size
    ks = jax.random.split(key, 4)
    xh = jax.random.normal(ks[0], (L, H, P))
    bm = jax.random.normal(ks[1], (L, N))
    cm = jax.random.normal(ks[2], (L, N))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (L, H)))
    a = -jnp.abs(jax.random.normal(key, (H,))) - 0.1
    y_ref, state_ref, dec_ref, cum_ref = ref.mamba_chunk_ref(
        xh, bm, cm, dt, a)

    # recurrent accumulation of the same chunk
    s = jnp.zeros((H, N, P))
    for t in range(L):
        da = jnp.exp(dt[t] * a)
        s = s * da[:, None, None] + jnp.einsum(
            "n,h,hp->hnp", bm[t], dt[t], xh[t])
    np.testing.assert_allclose(s, state_ref, atol=1e-4, rtol=1e-3)
