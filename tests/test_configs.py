"""Assigned-architecture configs match the published shapes exactly."""
import pytest

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_config

# (id, family, L, d_model, H, KV, d_ff, vocab, experts, top_k)
ASSIGNED = [
    ("phi3.5-moe-42b-a6.6b", "moe", 32, 4096, 32, 8, 6400, 32064, 16, 2),
    ("zamba2-7b", "hybrid", 81, 3584, 32, 32, 14336, 32000, 0, 0),
    ("internvl2-1b", "vlm", 24, 896, 14, 2, 4864, 151655, 0, 0),
    ("granite-moe-1b-a400m", "moe", 24, 1024, 16, 8, 512, 49155, 32, 8),
    ("whisper-base", "audio", 6, 512, 8, 8, 2048, 51865, 0, 0),
    ("llama3-405b", "dense", 126, 16384, 128, 8, 53248, 128256, 0, 0),
    ("qwen1.5-110b", "dense", 80, 8192, 64, 8, 49152, 152064, 0, 0),
    ("xlstm-1.3b", "ssm", 48, 2048, 4, 4, 0, 50304, 0, 0),
    ("qwen3-32b", "dense", 64, 5120, 64, 8, 25600, 151936, 0, 0),
    ("nemotron-4-15b", "dense", 32, 6144, 48, 8, 24576, 256000, 0, 0),
]


@pytest.mark.parametrize(
    "arch,family,L,d,H,KV,ff,vocab,E,K", ASSIGNED,
    ids=[a[0] for a in ASSIGNED])
def test_exact_config(arch, family, L, d, H, KV, ff, vocab, E, K):
    cfg = get_config(arch)
    assert cfg.family == family
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == H
    assert cfg.n_kv_heads == KV
    assert cfg.d_ff == ff
    assert cfg.vocab_size == vocab
    assert cfg.n_experts == E
    assert cfg.top_k == K


def test_registry_complete():
    assert len(ARCH_IDS) == 10
    for a in ARCH_IDS:
        assert get_config(a).name == a


def test_arch_details():
    assert get_config("qwen1.5-110b").qkv_bias
    assert get_config("qwen3-32b").qk_norm
    assert get_config("nemotron-4-15b").mlp_type == "relu2"
    assert get_config("zamba2-7b").ssm_state == 64
    assert get_config("whisper-base").n_encoder_layers > 0
    assert get_config("internvl2-1b").n_patches > 0
    assert get_config("whisper-base").n_frames > 0


def test_input_shapes():
    s = INPUT_SHAPES
    assert s["train_4k"].seq_len == 4096 and s["train_4k"].global_batch == 256
    assert s["prefill_32k"].seq_len == 32768
    assert s["prefill_32k"].global_batch == 32
    assert s["decode_32k"].global_batch == 128
    assert s["long_500k"].seq_len == 524288 and s["long_500k"].global_batch == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_invariants(arch):
    r = get_config(arch).reduced()
    assert r.n_layers == 2
    assert r.d_model <= 512
    assert r.n_experts <= 4
    assert r.d_model % r.n_heads == 0 or r.head_dim
    assert r.vocab_size <= 1024


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_counts_sane(arch):
    cfg = get_config(arch)
    n = cfg.n_params()
    # order-of-magnitude sanity vs the published sizes
    published = {
        "phi3.5-moe-42b-a6.6b": 42e9, "zamba2-7b": 7e9,
        "internvl2-1b": 0.8e9, "granite-moe-1b-a400m": 1.3e9,
        "whisper-base": 0.07e9, "llama3-405b": 405e9,
        "qwen1.5-110b": 110e9, "xlstm-1.3b": 1.3e9,
        "qwen3-32b": 32e9, "nemotron-4-15b": 15e9,
    }[arch]
    assert 0.3 * published < n < 3.5 * published, (arch, n, published)
    assert cfg.n_active_params() <= n
