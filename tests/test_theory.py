"""Paper Sec 4 quantitative theory: Lambert-W closed form, T* roots,
asymptotics, decay-order detection, and the adaptive controller."""
import math

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import theory
from repro.core.controller import AdaptiveT


# ---------------------------------------------------------------------------
# Lambert W (negative branch)
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(x=st.floats(-1.0 / math.e + 1e-12, -1e-12))
def test_lambert_w_identity(x):
    w = theory.lambert_w_neg(x)
    assert w <= -1.0 + 1e-8
    assert abs(w * math.exp(w) - x) < 1e-8 * max(1.0, abs(x))


def test_lambert_w_boundary():
    assert abs(theory.lambert_w_neg(-1.0 / math.e) + 1.0) < 1e-12
    with pytest.raises(ValueError):
        theory.lambert_w_neg(0.5)


# ---------------------------------------------------------------------------
# T* — linearly convergent local GD (h(t) = beta^t)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("beta", [0.5, 0.8, 0.95])
@pytest.mark.parametrize("r", [0.1, 0.01, 0.001])
def test_t_star_linear_matches_bruteforce(beta, r):
    """The formula's T achieves (near-)optimal cost under the discrete
    objective that the brute force minimizes (the formula minimizes the
    continuous bound; the argmins can differ where the cost is flat)."""
    t_formula = max(int(round(theory.t_star_linear(beta, r))), 1)
    h = lambda t: beta ** t
    t_brute = theory.t_star_numeric(r, h, t_max=100_000)
    c_formula = theory.cost_bound(t_formula, r, h)
    c_brute = theory.cost_bound(t_brute, r, h)
    assert c_formula <= 1.1 * c_brute, (t_formula, t_brute,
                                        c_formula, c_brute)


def test_t_star_linear_asymptotic():
    beta = 0.9
    for r in [1e-3, 1e-5]:
        exact = theory.t_star_linear(beta, r)
        asym = theory.t_star_linear_asymptotic(beta, r)
        assert abs(exact - asym) / exact < 0.2


# ---------------------------------------------------------------------------
# T* — sub-linearly convergent local GD (h(t) = (1+at)^-beta)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("a,beta", [(2.0, 1.5), (1.0, 2.0), (4.0, 1.2)])
@pytest.mark.parametrize("r", [0.01, 0.001])
def test_t_star_sublinear_root(a, beta, r):
    t = theory.t_star_sublinear(a, beta, r)
    # satisfies paper Eq. (6)
    g = r * ((1 + a * t) ** beta - 1) - a * (beta + beta * r * t - 1)
    scale = r * (1 + a * t) ** beta + a * beta
    assert abs(g) < 1e-6 * scale


@pytest.mark.parametrize("a,beta", [(2.0, 1.5), (1.0, 2.0)])
def test_t_star_sublinear_matches_bruteforce(a, beta):
    """Near-optimal cost: Eq-6 minimizes the integral-comparison bound,
    the brute force the discrete sum — argmins differ on flat costs, but
    the achieved cost must be within 15%."""
    r = 0.001
    t_formula = max(int(round(theory.t_star_sublinear(a, beta, r))), 1)
    h = lambda t: (1.0 + a * t) ** (-beta)
    t_brute = theory.t_star_numeric(r, h, t_max=1_000_000)
    c_formula = theory.cost_bound(t_formula, r, h)
    c_brute = theory.cost_bound(t_brute, r, h)
    assert c_formula <= 1.15 * c_brute, (t_formula, t_brute,
                                         c_formula, c_brute)


def test_t_star_sublinear_asymptotic():
    a, beta = 2.0, 1.5
    for r in [1e-4, 1e-6]:
        exact = theory.t_star_sublinear(a, beta, r)
        asym = theory.t_star_sublinear_asymptotic(a, beta, r)
        assert abs(exact - asym) / exact < 0.2


def test_regime_scaling():
    """Paper's qualitative conclusion: linear case T* ~ log(1/r), sublinear
    T* ~ r^(-1/beta) — so for small r the sublinear T* is much larger."""
    r = 1e-6
    t_lin = theory.t_star_linear(0.5, r)
    t_sub = theory.t_star_sublinear(2.0, 1.5, r)
    assert t_sub > 10 * t_lin, (t_lin, t_sub)


def test_quartic_h_params():
    a, beta = theory.quartic_h_params(l=2)
    assert a == 2.0 and beta == 1.5


# ---------------------------------------------------------------------------
# Rates
# ---------------------------------------------------------------------------


def test_alpha_sign():
    assert theory.alpha(0.5, 2.0) > 0     # eta < 2/L
    assert theory.alpha(1.5, 2.0) < 0     # eta > 2/L


def test_theorem3_rho_range():
    rho = theory.theorem3_rho([0.1], [1.0], [0.5], c=2.0)
    assert 0.0 < rho < 1.0
    # stronger convexity (bigger mu) -> faster rate (smaller rho)
    rho2 = theory.theorem3_rho([0.1], [1.0], [0.9], c=2.0)
    assert rho2 < rho


# ---------------------------------------------------------------------------
# Decay-order detection + adaptive controller
# ---------------------------------------------------------------------------


def test_fit_decay_linear():
    beta = 0.8
    traj = [beta ** t for t in range(20)]
    fit = theory.fit_decay(traj)
    assert fit.kind == "linear"
    assert abs(fit.beta - beta) < 0.05


def test_fit_decay_sublinear():
    a, beta = 2.0, 1.5
    traj = [(1 + a * t) ** (-beta) for t in range(40)]
    fit = theory.fit_decay(traj)
    assert fit.kind == "sublinear"
    assert abs(fit.beta - beta) < 0.5


def test_fit_decay_degenerate():
    assert theory.fit_decay([1.0]) is None
    assert theory.fit_decay([0.0, 0.0, 0.0]) is None


def test_adaptive_controller_converges_to_tstar():
    r, beta = 0.01, 0.9
    ctl = AdaptiveT(r=r, ema=0.0)  # no smoothing: jump straight to T*
    traj = [beta ** t for t in range(30)]
    t = ctl.update(traj)
    want = theory.t_star_linear(beta, r)
    assert abs(t - want) <= 2.0


def test_adaptive_controller_clips():
    ctl = AdaptiveT(r=1e-12, t_max=50, ema=0.0)
    traj = [(1 + 2.0 * t) ** (-1.5) for t in range(30)]
    assert ctl.update(traj) == 50
