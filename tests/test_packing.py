"""Flat-buffer engine (optim.packing + packed optimizers + packed rounds).

Acceptance-critical invariants:
  * pack/unpack roundtrip preserves shapes, dtypes, and values,
  * packed fused rounds == per-leaf pytree rounds for sgd / momentum /
    adamw over a full multi-round run, with average_opt_state on AND off
    (params and opt state within 1e-5),
  * the same parity holds on a real transformer loss,
  * metric contract: "traj" matches the pytree round's metrics exactly;
    "final" evaluates at the round's result,
  * modes not on the fast path raise instead of silently degrading.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import localsgd as lsgd
from repro.optim import packing


def quad_loss(params, batch):
    r = batch["A"] @ params["w"] - batch["b"]
    return 0.5 * jnp.sum(r ** 2) + 0.1 * jnp.sum(params["u"] ** 2)


def make_problem(key, G=3, r=4, d=6):
    ks = jax.random.split(key, 4)
    A = jax.random.normal(ks[0], (G, r, d)) / np.sqrt(d)
    w_star = jax.random.normal(ks[1], (d,))
    batch = {"A": A, "b": jnp.einsum("grd,d->gr", A, w_star)}
    params = {"w": jax.random.normal(ks[2], (d,)),
              "u": jax.random.normal(ks[3], (2, 3))}
    return params, batch


# ---------------------------------------------------------------------------
# layout / pack / unpack
# ---------------------------------------------------------------------------


def test_pack_unpack_roundtrip(key):
    ks = jax.random.split(key, 3)
    tree = {"a": jax.random.normal(ks[0], (3, 4)),
            "b": {"c": jax.random.normal(ks[1], (5,)).astype(jnp.bfloat16),
                  "d": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "e": jnp.float32(2.5)}
    layout = packing.layout_of(tree)
    buf = packing.pack(tree, layout)
    assert buf.shape == (layout.size,) and buf.dtype == jnp.float32
    assert layout.size == 12 + 5 + 6 + 1
    back = packing.unpack(buf, layout)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_pack_unpack_group_axis(key):
    G = 4
    tree = {"a": jax.random.normal(key, (3, 4)), "b": jnp.ones((5,))}
    layout = packing.layout_of(tree)
    tree_G = lsgd.replicate(tree, G)
    buf_G = packing.pack(tree_G, layout)
    assert buf_G.shape == (G, layout.size)
    back = packing.unpack(buf_G, layout)
    for a, b in zip(jax.tree.leaves(tree_G), jax.tree.leaves(back)):
        np.testing.assert_allclose(a, b)


def test_layout_abstract_matches_pack(key):
    tree = {"a": jnp.ones((3, 4)), "b": jnp.ones((5,))}
    layout = packing.layout_of(tree)
    abs_ = layout.abstract((2,))
    assert abs_.shape == (2, layout.size) and abs_.dtype == jnp.float32


def test_value_and_flat_grad_matches_tree_grad(key):
    params, batch = make_problem(key)
    layout = packing.layout_of(params)
    b0 = {"A": batch["A"][0], "b": batch["b"][0]}
    loss_t, g_tree = jax.value_and_grad(quad_loss)(params, b0)
    loss_f, g_flat = packing.value_and_flat_grad(quad_loss, layout)(
        packing.pack(params, layout), b0)
    np.testing.assert_allclose(loss_f, loss_t, rtol=1e-6)
    np.testing.assert_allclose(g_flat, packing.pack(g_tree, layout),
                               rtol=1e-6, atol=1e-7)


def test_average_groups_flat_matches_per_leaf(key):
    params, _ = make_problem(key)
    layout = packing.layout_of(params)
    G = 3
    tree_G = jax.tree.map(
        lambda x: x[None] * jnp.arange(1., G + 1).reshape((G,) + (1,) * x.ndim),
        params)
    per_leaf = lsgd.average_groups(tree_G)
    flat = lsgd.average_groups(packing.pack(tree_G, layout))
    np.testing.assert_allclose(flat, packing.pack(per_leaf, layout),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# packed round == per-leaf pytree round (the acceptance parity)
# ---------------------------------------------------------------------------


MOMENT_KEYS = {"sgd": [], "momentum": ["mu"], "adamw": ["m", "v"]}


@pytest.mark.parametrize("name", ["sgd", "momentum", "adamw"])
@pytest.mark.parametrize("avg_opt", [True, False])
def test_packed_round_parity(name, avg_opt, key):
    """Full multi-round run: params AND opt state agree within 1e-5."""
    params, batch = make_problem(key)
    G = 3
    layout = packing.layout_of(params)
    opt_t = optim.get(name, 0.05)
    opt_p = optim.get(name, 0.05, packed=True, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=4,
                              average_opt_state=avg_opt, metrics="traj")
    rnd_t = jax.jit(lsgd.make_local_round(quad_loss, opt_t, cfg))
    rnd_p = jax.jit(lsgd.make_local_round(quad_loss, opt_p, cfg,
                                          layout=layout))
    st = lsgd.init_state(params, opt_t, n_groups=G)
    sp = lsgd.init_state(params, opt_p, n_groups=G, layout=layout)
    for _ in range(3):
        st, mt = rnd_t(st, batch)
        sp, mp = rnd_p(sp, batch)

    wt = lsgd.server_params(st)
    wp = lsgd.server_params(sp, layout=layout)
    for a, b in zip(jax.tree.leaves(wt), jax.tree.leaves(wp)):
        np.testing.assert_allclose(b, a, rtol=1e-5, atol=1e-6)
    # opt-state parity: packed moment buffers == packed per-leaf moments
    for mk in MOMENT_KEYS[name]:
        for g in range(G):
            ref = packing.pack(
                jax.tree.map(lambda x: x[g], st["opt"][mk]), layout)
            np.testing.assert_allclose(sp["opt"][mk][g], ref,
                                       rtol=1e-5, atol=1e-6)
    # metric parity in traj mode
    np.testing.assert_allclose(mp["loss"], mt["loss"], rtol=1e-4,
                               atol=1e-7)
    np.testing.assert_allclose(mp["grad_sq_traj"], mt["grad_sq_traj"],
                               rtol=1e-4, atol=1e-8)


@pytest.mark.parametrize("name", ["sgd", "momentum", "adamw"])
def test_packed_round_parity_pallas_kernels(name, key):
    """Same parity through the fused Pallas kernels (interpret on CPU)."""
    params, batch = make_problem(key)
    G = 2
    layout = packing.layout_of(params)
    opt_t = optim.get(name, 0.05)
    opt_p = optim.get(name, 0.05, packed=True, impl="pallas")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=3)
    batch2 = {"A": batch["A"][:G], "b": batch["b"][:G]}
    rnd_t = jax.jit(lsgd.make_local_round(quad_loss, opt_t, cfg))
    rnd_p = jax.jit(lsgd.make_local_round(quad_loss, opt_p, cfg,
                                          layout=layout))
    st = lsgd.init_state(params, opt_t, n_groups=G)
    sp = lsgd.init_state(params, opt_p, n_groups=G, layout=layout)
    st, _ = rnd_t(st, batch2)
    sp, _ = rnd_p(sp, batch2)
    for a, b in zip(jax.tree.leaves(lsgd.server_params(st)),
                    jax.tree.leaves(lsgd.server_params(sp, layout=layout))):
        np.testing.assert_allclose(b, a, rtol=1e-5, atol=1e-6)


def test_packed_round_parity_real_model(key):
    """Parity holds on an actual transformer loss (reduced paper-mlp)."""
    from repro.configs.base import get_config
    from repro.models import build_model

    cfg = get_config("paper-mlp").reduced()
    model = build_model(cfg, schedule="rect")
    params = model.init(jax.random.PRNGKey(0))
    layout = packing.layout_of(params)
    G = 2
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab_size, (G, 1, 16)), jnp.int32)}
    lcfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2, metrics="traj")
    opt_t, opt_p = optim.sgd(0.05), optim.packed("sgd", 0.05, impl="jnp")
    rnd_t = jax.jit(lsgd.make_local_round(model.loss, opt_t, lcfg))
    rnd_p = jax.jit(lsgd.make_local_round(model.loss, opt_p, lcfg,
                                          layout=layout))
    st = lsgd.init_state(params, opt_t, n_groups=G)
    sp = lsgd.init_state(params, opt_p, n_groups=G, layout=layout)
    st, mt = rnd_t(st, batch)
    sp, mp = rnd_p(sp, batch)
    np.testing.assert_allclose(mp["loss"], mt["loss"], rtol=1e-5)
    for a, b in zip(jax.tree.leaves(lsgd.server_params(st)),
                    jax.tree.leaves(lsgd.server_params(sp, layout=layout))):
        np.testing.assert_allclose(b, a, rtol=1e-5, atol=1e-6)


def test_packed_t_i_parity(key):
    params, batch = make_problem(key)
    G = 3
    layout = packing.layout_of(params)
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=8, t_i=(1, 4, 8))
    opt_t, opt_p = optim.sgd(0.05), optim.packed("sgd", 0.05, impl="jnp")
    rnd_t = jax.jit(lsgd.make_local_round(quad_loss, opt_t, cfg))
    rnd_p = jax.jit(lsgd.make_local_round(quad_loss, opt_p, cfg,
                                          layout=layout))
    st = lsgd.init_state(params, opt_t, n_groups=G)
    sp = lsgd.init_state(params, opt_p, n_groups=G, layout=layout)
    st, mt = rnd_t(st, batch)
    sp, mp = rnd_p(sp, batch)
    assert list(np.asarray(mp["inner_steps"])) == [1, 4, 8]
    for a, b in zip(jax.tree.leaves(lsgd.server_params(st)),
                    jax.tree.leaves(lsgd.server_params(sp, layout=layout))):
        np.testing.assert_allclose(b, a, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_packed_t_i_adamw_parity(impl, key):
    """The PR-1 leftover, lifted (DESIGN.md §10): per-node t_i with a
    count-dependent update runs the fused step vmapped over G with a
    PER-GROUP count vector. Multi-round parity vs the pytree path for
    params, moments, AND the per-group counters (count_g = r * t_i[g])."""
    params, batch = make_problem(key)
    G = 3
    layout = packing.layout_of(params)
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=8, t_i=(1, 4, 8))
    opt_t = optim.adamw(0.01)
    opt_p = optim.packed("adamw", 0.01, impl=impl)
    rnd_t = jax.jit(lsgd.make_local_round(quad_loss, opt_t, cfg))
    rnd_p = jax.jit(lsgd.make_local_round(quad_loss, opt_p, cfg,
                                          layout=layout))
    st = lsgd.init_state(params, opt_t, n_groups=G)
    sp = lsgd.init_state(params, opt_p, n_groups=G, layout=layout)
    for _ in range(2):
        st, mt = rnd_t(st, batch)
        sp, mp = rnd_p(sp, batch)
    assert list(np.asarray(mp["inner_steps"])) == [1, 4, 8]
    # per-group counters stopped at t_i, matching the pytree masking
    np.testing.assert_array_equal(np.asarray(sp["opt"]["count"]),
                                  np.asarray(st["opt"]["count"]))
    np.testing.assert_array_equal(np.asarray(sp["opt"]["count"]),
                                  np.asarray([2, 8, 16], np.int32))
    for a, b in zip(jax.tree.leaves(lsgd.server_params(st)),
                    jax.tree.leaves(lsgd.server_params(sp, layout=layout))):
        np.testing.assert_allclose(b, a, rtol=1e-5, atol=1e-6)
    for mk in ("m", "v"):
        for g in range(G):
            ref = packing.pack(
                jax.tree.map(lambda x: x[g], st["opt"][mk]), layout)
            np.testing.assert_allclose(sp["opt"][mk][g], ref,
                                       rtol=1e-5, atol=1e-7)


def test_packed_t_i_schedule_parity(key):
    """lr schedules are count-dependent too: under t_i they take the same
    vmapped per-group-count path and match the pytree round."""
    params, batch = make_problem(key)
    G = 2
    layout = packing.layout_of(params)
    lr_fn = optim.cosine_schedule(0.1, warmup=2, total=20)
    opt_t = optim.with_schedule(optim.sgd, lr_fn)
    opt_p = optim.with_schedule(
        lambda lr: optim.packed("sgd", lr, impl="jnp"), lr_fn)
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=4, t_i=(1, 4))
    batch2 = {"A": batch["A"][:G], "b": batch["b"][:G]}
    rnd_t = jax.jit(lsgd.make_local_round(quad_loss, opt_t, cfg))
    rnd_p = jax.jit(lsgd.make_local_round(quad_loss, opt_p, cfg,
                                          layout=layout))
    st = lsgd.init_state(params, opt_t, n_groups=G)
    sp = lsgd.init_state(params, opt_p, n_groups=G, layout=layout)
    for _ in range(2):
        st, _ = rnd_t(st, batch2)
        sp, _ = rnd_p(sp, batch2)
    np.testing.assert_array_equal(np.asarray(sp["opt"]["count"]),
                                  np.asarray(st["opt"]["count"]))
    for a, b in zip(jax.tree.leaves(lsgd.server_params(st)),
                    jax.tree.leaves(lsgd.server_params(sp, layout=layout))):
        np.testing.assert_allclose(b, a, rtol=1e-5, atol=1e-6)


def test_packed_sync_step_parity(key):
    params, batch = make_problem(key)
    layout = packing.layout_of(params)
    b0 = {"A": batch["A"][0], "b": batch["b"][0]}
    opt_t, opt_p = optim.adamw(0.01), optim.packed("adamw", 0.01,
                                                   impl="jnp")
    st = lsgd.init_state(params, opt_t)
    sp = lsgd.init_state(params, opt_p, layout=layout)
    step_t = jax.jit(lsgd.make_sync_step(quad_loss, opt_t))
    step_p = jax.jit(lsgd.make_sync_step(quad_loss, opt_p, layout=layout))
    for _ in range(3):
        st, mt = step_t(st, b0)
        sp, mp = step_p(sp, b0)
    np.testing.assert_allclose(mp["grad_sq"], mt["grad_sq"], rtol=1e-4)
    ref = packing.pack(st["params"], layout)
    np.testing.assert_allclose(sp["params"], ref, rtol=1e-5, atol=1e-6)


def test_final_metrics_contract(key):
    """metrics="final" (default) reports loss/||grad||^2 at the round's
    RESULT — i.e. the grad_sq one update later than traj's last entry."""
    params, batch = make_problem(key)
    G = 3
    layout = packing.layout_of(params)
    opt_p = optim.packed("sgd", 0.05, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=4)   # default final
    assert cfg.metrics == "final"
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt_p, cfg,
                                        layout=layout))
    sp = lsgd.init_state(params, opt_p, n_groups=G, layout=layout)
    new_sp, m = rnd(sp, batch)
    from repro import obs
    assert set(m) == set(obs.round_metric_keys(("params",)))
    # per-stream split sums to the old total (sgd: params only)
    assert int(m["wire_bytes/params"]) == int(m["wire_bytes"])
    # the traj round reports the gradient made AT step T-1; final mode is
    # one descent update later, so on this convex problem it must be <=
    cfg_traj = dataclasses.replace(cfg, metrics="traj")
    rnd_traj = jax.jit(lsgd.make_local_round(quad_loss, opt_p, cfg_traj,
                                             layout=layout))
    _, m_traj = rnd_traj(jax.tree.map(jnp.copy, sp), batch)
    # final-mode grad_sq must be <= traj's last recorded grad_sq for this
    # convex descent problem (one more update happened)
    assert np.all(np.asarray(m["grad_sq"])
                  <= np.asarray(m_traj["grad_sq"]) + 1e-8)


def test_packed_survives_schedule_and_clip_wrappers(key):
    """with_schedule/clip_by_global_norm must keep the packed/impl flags
    so the wrapped optimizer still routes to the flat-buffer path."""
    params, batch = make_problem(key)
    G = 2
    layout = packing.layout_of(params)
    # max_norm small enough to BIND: per-group clipping must also agree
    lr_fn = optim.cosine_schedule(0.05, warmup=2, total=20)
    opt_p = optim.clip_by_global_norm(
        optim.with_schedule(lambda lr: optim.packed("sgd", lr, impl="jnp"),
                            lr_fn), max_norm=0.5)
    opt_t = optim.clip_by_global_norm(
        optim.with_schedule(optim.sgd, lr_fn), max_norm=0.5)
    assert opt_p.packed and not opt_t.packed
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=3)
    batch2 = {"A": batch["A"][:G], "b": batch["b"][:G]}
    rnd_p = jax.jit(lsgd.make_local_round(quad_loss, opt_p, cfg,
                                          layout=layout))
    rnd_t = jax.jit(lsgd.make_local_round(quad_loss, opt_t, cfg))
    sp = lsgd.init_state(params, opt_p, n_groups=G, layout=layout)
    st = lsgd.init_state(params, opt_t, n_groups=G)
    sp, _ = rnd_p(sp, batch2)
    st, _ = rnd_t(st, batch2)
    for a, b in zip(jax.tree.leaves(lsgd.server_params(st)),
                    jax.tree.leaves(lsgd.server_params(sp, layout=layout))):
        np.testing.assert_allclose(b, a, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------


def test_packed_requires_layout_and_packed_opt(key):
    params, _ = make_problem(key)
    layout = packing.layout_of(params)
    cfg = lsgd.LocalSGDConfig(n_groups=2, inner_steps=2)
    with pytest.raises(ValueError):
        lsgd.make_local_round(quad_loss, optim.packed("sgd", 0.1), cfg)
    with pytest.raises(ValueError):
        lsgd.make_local_round(quad_loss, optim.sgd(0.1), cfg,
                              layout=layout)
    with pytest.raises(ValueError):
        lsgd.make_sync_step(quad_loss, optim.packed("sgd", 0.1))


def test_packed_unsupported_modes_raise(key):
    params, _ = make_problem(key)
    layout = packing.layout_of(params)
    opt_p = optim.packed("sgd", 0.1)
    with pytest.raises(NotImplementedError):
        lsgd.make_local_round(
            quad_loss, opt_p,
            lsgd.LocalSGDConfig(n_groups=2, inner_steps=2, threshold=1e-3),
            layout=layout)
    with pytest.raises(NotImplementedError):
        # the pytree path silently ignores t_i under microbatch; the
        # packed path refuses rather than silently diverging from it
        lsgd.make_local_round(
            quad_loss, opt_p,
            lsgd.LocalSGDConfig(n_groups=2, inner_steps=2, t_i=(1, 2),
                                inner_mode="microbatch"),
            layout=layout)


def test_build_packed_train_step_rejects_policy():
    from repro.configs.base import get_config, InputShape
    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import build_train_step

    cfg = get_config("paper-mlp").reduced()
    mesh = make_local_mesh(1, 1)
    shape = InputShape(name="tiny", kind="train", global_batch=4,
                       seq_len=8)
    with pytest.raises(NotImplementedError):
        build_train_step(cfg, shape, mesh, packed=True, policy="dp")


# ---------------------------------------------------------------------------
# packed train-step builder + donation
# ---------------------------------------------------------------------------


def test_build_packed_train_step():
    from repro.configs.base import get_config, InputShape
    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import build_train_step

    cfg = get_config("paper-mlp").reduced()
    mesh = make_local_mesh(1, 1)
    shape = InputShape(name="tiny", kind="train", global_batch=4,
                       seq_len=8)
    built = build_train_step(cfg, shape, mesh, t_inner=2, opt_name="adamw",
                             packed=True)
    assert built.donate_argnums == (0,)
    assert built.meta["packed"] is True
    state_abs, batch_abs = built.args
    n = built.meta["n_flat"]
    assert state_abs["params"].shape[-1] == n
    assert state_abs["opt"]["m"].shape == state_abs["params"].shape
    # lower+compile on the host mesh to prove the packed round is jittable
    jitted = jax.jit(built.fn, donate_argnums=built.donate_argnums)
    jitted.lower(*built.args).compile()


def test_fused_ops_donation_memory_analysis():
    """ops.fused_adamw donates p/m/v: the compiled memory analysis must
    show the donated bytes as aliased (no extra output copies)."""
    from repro.kernels import ops

    n = 4096
    p = jax.ShapeDtypeStruct((n,), jnp.float32)
    c = jax.ShapeDtypeStruct((), jnp.int32)
    lowered = ops.fused_adamw.lower(p, p, p, p, c, 1e-3)
    ma = lowered.compile().memory_analysis()
    if ma is None or not hasattr(ma, "alias_size_in_bytes"):
        pytest.skip("backend exposes no memory analysis")
    # p, m, v donated -> at least 3 * n * 4 bytes aliased in place, and
    # no un-aliased full-buffer output copy remains
    assert ma.alias_size_in_bytes >= 3 * n * 4
    assert ma.output_size_in_bytes - ma.alias_size_in_bytes < n * 4

    lowered = ops.fused_sgd.lower(p, p, 1e-3)
    ma = lowered.compile().memory_analysis()
    assert ma.alias_size_in_bytes >= n * 4


# ---------------------------------------------------------------------------
# StreamLayout: the multi-stream payload contract (DESIGN.md §10)
# ---------------------------------------------------------------------------


def test_stream_layout_contract(key):
    params, _ = make_problem(key)
    layout = packing.layout_of(params)
    for name, streams in (("sgd", ("params",)),
                          ("momentum", ("params", "mu")),
                          ("adamw", ("params", "m", "v"))):
        opt = optim.packed(name, 0.1, impl="jnp")
        sl = packing.stream_layout_for(opt, layout)
        assert sl.streams == streams
        assert sl.moment_streams == streams[1:]
        assert sl.n_streams == len(streams)
        assert sl.sizes() == {s: layout.padded for s in streams}
        # abstract matches what opt.init actually allocates
        buf_G = layout.abstract((3,))
        opt_abs = jax.eval_shape(opt.init, buf_G)
        abs_ = sl.abstract((3,))
        for s in sl.moment_streams:
            assert opt_abs[s].shape == abs_[s].shape
    # the declared streams ARE the state's non-count keys
    opt = optim.packed("adamw", 0.1, impl="jnp")
    state = opt.init(packing.pack(params, layout))
    assert set(opt.moment_keys) == set(state) - {"count"}


def test_stream_layout_stacked_view(key):
    """stack/unstack: one (S, ..., Np) view of the whole payload for
    fused whole-payload kernels — round-trips exactly, streams in
    declared order."""
    params, _ = make_problem(key)
    layout = packing.shard_layout(packing.layout_of(params), 2, align=64)
    opt = optim.packed("adamw", 0.1, impl="jnp")
    sl = packing.stream_layout_for(opt, layout)
    G = 3
    ks = jax.random.split(key, sl.n_streams)
    bufs = {name: jax.random.normal(k, (G, layout.padded))
            for name, k in zip(sl.streams, ks)}
    stacked = sl.stack(bufs)
    assert stacked.shape == (3, G, layout.padded)
    np.testing.assert_array_equal(stacked[sl.index("m")], bufs["m"])
    back = sl.unstack(stacked)
    for name in sl.streams:
        np.testing.assert_array_equal(back[name], bufs[name])


def test_builder_meta_wire_bytes_by_stream():
    """The packed builder's meta resolves wire bytes per stream and the
    totals are exact sums (adamw + int8 moments)."""
    from repro.configs.base import get_config, InputShape
    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import build_train_step

    from repro import comm

    cfg = get_config("paper-mlp").reduced()
    mesh = make_local_mesh(1, 1)
    shape = InputShape(name="tiny", kind="train", global_batch=4,
                       seq_len=8)
    built = build_train_step(cfg, shape, mesh, t_inner=2, opt_name="adamw",
                             packed=True, codec="int8",
                             moment_codec="int8")
    meta = built.meta
    assert meta["streams"] == ["params", "m", "v"]
    by = meta["wire_bytes_per_round_by_stream"]
    assert set(by) == {"params", "m", "v"}
    assert meta["wire_bytes_per_round"] == sum(by.values())
    n = meta["n_flat_padded"]
    ex = comm.get_exchange("server", "int8", meta["groups"],
                           moment_codec="int8")
    assert by == ex.wire_bytes_by_stream(n, {"m": n, "v": n})
    # comm state carries the three per-stream rng counters
    assert set(built.args[0]["comm"]["codec"]) == {"params", "m", "v"}
