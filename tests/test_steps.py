"""End-to-end step builders (launch.steps) on the real 1-device mesh with
reduced configs and materialized values — validates that the exact code
path used by the production dry-run also *runs*."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs.base import InputShape, get_config
from repro.core import localsgd as lsgd
from repro.data.synthetic import TokenPipeline
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import build_step
from repro.models import build_model

SMALL_TRAIN = InputShape("train_small", 32, 4, "train")
SMALL_PREFILL = InputShape("prefill_small", 64, 2, "prefill")
SMALL_DECODE = InputShape("decode_small", 64, 2, "decode")


def materialize(model, built, cfg, shape, key):
    """Real values matching BuiltStep's abstract args."""
    params = model.init(key)
    out = []
    for a in built.args:
        leaves = jax.tree.leaves(a)
        if leaves and all(hasattr(x, "shape") for x in leaves):
            pass
        out.append(a)
    return params


def make_values(abs_tree, key):
    def mk(leaf):
        if jnp.issubdtype(leaf.dtype, jnp.integer):
            return jnp.zeros(leaf.shape, leaf.dtype)
        return jax.random.normal(key, leaf.shape, jnp.float32).astype(
            leaf.dtype) * 0.02
    return jax.tree.map(mk, abs_tree)


@pytest.mark.parametrize("arch", ["qwen3-32b", "granite-moe-1b-a400m",
                                  "xlstm-1.3b", "zamba2-7b",
                                  "whisper-base", "internvl2-1b"])
def test_localsgd_train_step_runs(arch, key):
    cfg = get_config(arch).reduced()
    mesh = make_local_mesh(1, 1)
    built = build_step(cfg, SMALL_TRAIN, mesh, t_inner=2)
    assert built.meta["mode"] == "localsgd"
    model = build_model(cfg, schedule="rect")
    params = model.init(key)
    G = built.meta["groups"]
    state = lsgd.init_state(params, optim.sgd(1e-3), n_groups=G)
    batch = make_values(built.args[1], key)
    pipe = TokenPipeline(cfg.vocab_size, SMALL_TRAIN.seq_len)
    batch["tokens"] = jnp.asarray(
        next(pipe.batches((G, SMALL_TRAIN.global_batch // G)))["tokens"])
    with mesh:
        new_state, metrics = jax.jit(built.fn)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]).all())
    # params actually moved
    delta = sum(float(jnp.sum(jnp.abs(a - b))) for a, b in zip(
        jax.tree.leaves(new_state["params"]),
        jax.tree.leaves(state["params"])))
    assert delta > 0


def test_sync_train_step_runs(key):
    cfg = get_config("qwen3-32b").reduced()
    mesh = make_local_mesh(1, 1)
    built = build_step(cfg, SMALL_TRAIN, mesh, mode="sync")
    assert built.meta["mode"] == "sync"
    model = build_model(cfg, schedule="rect")
    params = model.init(key)
    state = lsgd.init_state(params, optim.sgd(1e-3))
    batch = make_values(built.args[1], key)
    with mesh:
        new_state, metrics = jax.jit(built.fn)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))


@pytest.mark.parametrize("arch", ["qwen3-32b", "whisper-base"])
def test_prefill_step_runs(arch, key):
    cfg = get_config(arch).reduced()
    mesh = make_local_mesh(1, 1)
    built = build_step(cfg, SMALL_PREFILL, mesh)
    model = build_model(cfg, schedule="rect")
    params = model.init(key)
    batch = make_values(built.args[1], key)
    with mesh:
        logits = jax.jit(built.fn)(params, batch)
    assert logits.shape == (SMALL_PREFILL.global_batch, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ["qwen3-32b", "zamba2-7b", "xlstm-1.3b"])
def test_decode_step_runs(arch, key):
    cfg = get_config(arch).reduced()
    mesh = make_local_mesh(1, 1)
    built = build_step(cfg, SMALL_DECODE, mesh)
    model = build_model(cfg)
    params = model.init(key)
    cache = model.init_cache(SMALL_DECODE.global_batch,
                             built.meta["cache_len"])
    tok = jnp.zeros((SMALL_DECODE.global_batch, 1), jnp.int32)
    with mesh:
        logits, new_cache = jax.jit(built.fn)(
            params, cache, tok, jnp.asarray(0, jnp.int32))
    assert logits.shape[0] == SMALL_DECODE.global_batch
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_long500k_uses_sliding_window():
    cfg = get_config("qwen3-32b")  # full config; abstract only
    mesh = make_local_mesh(1, 1)
    long_shape = InputShape("long_500k", 524_288, 1, "decode")
    built = build_step(cfg, long_shape, mesh)
    assert built.meta["cache_len"] == cfg.long_context_window
    # SSM archs keep O(1) state; cache_len only affects attention archs
    cfg2 = get_config("xlstm-1.3b")
    built2 = build_step(cfg2, long_shape, mesh)
    assert built2.meta["mode"] == "decode"


def test_moe_impl_override(key):
    cfg = get_config("granite-moe-1b-a400m").reduced()
    mesh = make_local_mesh(1, 1)
    built = build_step(cfg, SMALL_TRAIN, mesh, t_inner=1,
                       moe_impl="dispatch")
    model = build_model(
        dataclasses.replace(cfg, moe_impl="dispatch"), schedule="rect")
    params = model.init(key)
    G = built.meta["groups"]
    state = lsgd.init_state(params, optim.sgd(1e-3), n_groups=G)
    batch = make_values(built.args[1], key)
    with mesh:
        _, metrics = jax.jit(built.fn)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]).all())
