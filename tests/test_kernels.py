"""Pallas kernels vs pure-jnp oracles (ref.py), swept over shapes/dtypes.

Kernels execute in interpret mode on CPU (the kernel body is validated;
the same pallas_call compiles with VMEM BlockSpecs on TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_adamw import fused_adamw
from repro.kernels.fused_momentum import fused_momentum
from repro.kernels.fused_sgd import fused_sgd
from repro.kernels.mamba_scan import mamba_chunk
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.sq_norm import sq_norm, sq_norm_groups

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,H,S,hd,bq,bk", [
    (1, 2, 128, 64, 128, 128),
    (2, 4, 256, 32, 128, 64),
    (1, 1, 512, 128, 128, 128),
    (1, 2, 256, 64, 64, 128),   # unequal q/k blocks
    (2, 1, 64, 16, 64, 64),     # single block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, H, S, hd, bq, bk, dtype, key):
    ks = jax.random.split(key, 3)
    q = rand(ks[0], (B, H, S, hd), dtype)
    k = rand(ks[1], (B, H, S, hd), dtype)
    v = rand(ks[2], (B, H, S, hd), dtype)
    out = flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32),
        atol=TOL[dtype], rtol=TOL[dtype] * 10)


def test_flash_attention_causality(key):
    """Perturbing a future kv position must not change earlier outputs."""
    B, H, S, hd = 1, 1, 128, 32
    ks = jax.random.split(key, 3)
    q = rand(ks[0], (B, H, S, hd), jnp.float32)
    k = rand(ks[1], (B, H, S, hd), jnp.float32)
    v = rand(ks[2], (B, H, S, hd), jnp.float32)
    out1 = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    k2 = k.at[:, :, -1].add(100.0)
    v2 = v.at[:, :, -1].add(100.0)
    out2 = flash_attention(q, k2, v2, block_q=64, block_k=64, interpret=True)
    np.testing.assert_allclose(out1[:, :, :-1], out2[:, :, :-1], atol=1e-6)
    assert not np.allclose(out1[:, :, -1], out2[:, :, -1])


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [
    (4, 64), (2, 8, 128), (1, 31, 33), (300, 256), (1, 1, 1, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype, key):
    ks = jax.random.split(key, 2)
    x = rand(ks[0], shape, dtype)
    w = rand(ks[1], shape[-1:], jnp.float32) + 1.0
    out = rmsnorm(x, w, eps=1e-5, block_rows=64, interpret=True)
    want = ref.rmsnorm_ref(x, w, eps=1e-5)
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32),
        atol=TOL[dtype], rtol=TOL[dtype] * 10)


# ---------------------------------------------------------------------------
# fused adamw
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [8, 1000, 70000])
@pytest.mark.parametrize("count,wd", [(1, 0.0), (7, 0.1), (100, 0.01)])
def test_fused_adamw(n, count, wd, key):
    ks = jax.random.split(key, 4)
    p = rand(ks[0], (n,), jnp.float32)
    g = rand(ks[1], (n,), jnp.float32)
    m = rand(ks[2], (n,), jnp.float32) * 0.1
    v = jnp.abs(rand(ks[3], (n,), jnp.float32)) * 0.01
    got = fused_adamw(p, g, m, v, count=count, lr=1e-3, wd=wd,
                      block=4096, interpret=True)
    want = ref.adamw_ref(p, g, m, v, count=count, lr=1e-3, wd=wd)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, atol=1e-6, rtol=1e-5)


# ---------------------------------------------------------------------------
# fused sgd / momentum (the packed local-GD hot path)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [8, 1000, 70000])
def test_fused_sgd(n, key):
    ks = jax.random.split(key, 2)
    p = rand(ks[0], (n,), jnp.float32)
    g = rand(ks[1], (n,), jnp.float32)
    got = fused_sgd(p, g, lr=0.1, block=4096, interpret=True)
    np.testing.assert_allclose(got, ref.sgd_ref(p, g, lr=0.1),
                               atol=1e-7, rtol=1e-5)


@pytest.mark.parametrize("n", [8, 1000, 70000])
@pytest.mark.parametrize("beta", [0.0, 0.9])
def test_fused_momentum(n, beta, key):
    ks = jax.random.split(key, 3)
    p = rand(ks[0], (n,), jnp.float32)
    g = rand(ks[1], (n,), jnp.float32)
    mu = rand(ks[2], (n,), jnp.float32) * 0.1
    got = fused_momentum(p, g, mu, lr=0.1, beta=beta, block=4096,
                         interpret=True)
    want = ref.momentum_ref(p, g, mu, lr=0.1, beta=beta)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, atol=1e-7, rtol=1e-5)


# ---------------------------------------------------------------------------
# fused squared-norm reduction
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [8, 1000, 70000])
def test_sq_norm(n, key):
    x = rand(key, (n,), jnp.float32)
    got = sq_norm(x, block=4096, interpret=True)
    np.testing.assert_allclose(got, ref.sq_norm_ref(x), rtol=1e-5)


@pytest.mark.parametrize("g,n", [(1, 64), (3, 1000), (4, 70000)])
def test_sq_norm_groups(g, n, key):
    x = rand(key, (g, n), jnp.float32)
    got = sq_norm_groups(x, block=4096, interpret=True)
    np.testing.assert_allclose(
        got, jnp.sum(jnp.square(x), axis=-1), rtol=1e-5)


# ---------------------------------------------------------------------------
# mamba chunk (SSD intra-chunk)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,c,L,H,N,P", [
    (1, 1, 8, 2, 4, 4),
    (2, 3, 16, 2, 8, 8),
    (1, 2, 128, 4, 64, 64),   # MXU-aligned production tile
])
def test_mamba_chunk(B, c, L, H, N, P, key):
    ks = jax.random.split(key, 5)
    xh = rand(ks[0], (B, c, L, H, P), jnp.float32)
    bm = rand(ks[1], (B, c, L, N), jnp.float32)
    cm = rand(ks[2], (B, c, L, N), jnp.float32)
    dt = jax.nn.softplus(rand(ks[3], (B, c, L, H), jnp.float32))
    a = -jnp.abs(rand(ks[4], (H,), jnp.float32)) - 0.1
    y, st, dec, cum = mamba_chunk(xh, bm, cm, dt, a, interpret=True)
    for b in range(B):
        for ci in range(c):
            yr, str_, decr, cumr = ref.mamba_chunk_ref(
                xh[b, ci], bm[b, ci], cm[b, ci], dt[b, ci], a)
            np.testing.assert_allclose(y[b, ci], yr, atol=1e-4, rtol=1e-4)
            np.testing.assert_allclose(st[b, ci], str_, atol=1e-4, rtol=1e-4)
            np.testing.assert_allclose(dec[b, ci], decr, atol=1e-5, rtol=1e-5)
            np.testing.assert_allclose(cum[b, ci], cumr, atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# jit'd public wrappers (ops.py)
# ---------------------------------------------------------------------------


def test_ops_wrappers_jit(key):
    q = rand(key, (1, 2, 128, 32), jnp.float32)
    out = ops.flash_attention(q, q, q)
    assert out.shape == q.shape
    x = rand(key, (4, 64), jnp.float32)
    w = jnp.ones((64,))
    assert ops.rmsnorm(x, w).shape == x.shape
    # p is donated by the wrapper: pass a distinct gradient buffer
    p = rand(key, (100,), jnp.float32)
    g = rand(key, (100,), jnp.float32) * 0.1
    new_p, new_m, new_v = ops.fused_adamw(
        p, g, jnp.zeros_like(p), jnp.zeros_like(p), 1, lr=1e-3)
    assert new_p.shape == g.shape
    new_p2 = ops.fused_sgd(jnp.copy(g), g, 1e-3)
    assert new_p2.shape == g.shape
    np.testing.assert_allclose(ops.sq_norm(g), jnp.sum(g * g), rtol=1e-5)
    # comm-codec wrappers: quantize/dequantize round-trip within the
    # per-chunk scale (stochastic rounding moves <= 1 step)
    x = rand(key, (3, 128), jnp.float32)
    u = jax.random.uniform(key, (3, 128))
    qv, scales = ops.quantize_int8(x, u)
    assert qv.dtype == jnp.int8 and scales.shape == (3, 1)
    back = ops.dequantize_int8(qv, scales)
    assert bool(jnp.all(jnp.abs(back - x) <= scales + 1e-7))
