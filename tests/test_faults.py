"""Fault-tolerant exchange (ISSUE 6 / DESIGN.md §12).

Acceptance-critical invariants:
  * FaultPlan masks are a PURE function of (round, seed): two
    instantiations agree bit-for-bit, replicated and shard_map paths
    consume identical masks, and a checkpoint resume replays the same
    fault schedule,
  * drop_rate=0 is normalized away (fault_plan is None) and every
    pre-existing topology stays bit-exact with the PR-5 exchange,
  * push_sum is ratio consensus with mass counters: the total mass
    (live + in-flight backlog) is conserved EXACTLY and the num/weight
    ratio converges to the true group mean even under packet loss —
    while ring/gossip under the same masks provably drift the mean
    (the bias-demonstration regression),
  * graceful degradation on the server/async paths: survivors
    averaging with a participation metric, bounded-staleness retry
    from the pushed buffers, and EF residuals that DEFER (not vanish)
    undelivered compressed payloads,
  * every get_exchange refusal names the valid alternatives,
  * a mid-fault checkpoint (nonzero staleness + EF residual + mass
    counters under an active FaultPlan) resumes bit-exactly.

8-device tests ride the same forced-host child-process pattern as
tests/test_shardexec.py (REPRO_SHARDEXEC_CHILD gates the in-suite
driver so CI's dedicated 8-device job doesn't pay twice).
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import comm, optim
from repro.comm import faults as faults_mod
from repro.comm import topology as topo
from repro.core import localsgd as lsgd
from repro.core.controller import AdaptiveT
from repro.optim import packing
from repro.sharding import shardexec as shx

HAVE8 = jax.device_count() >= 8
needs8 = pytest.mark.skipif(not HAVE8, reason="needs 8 devices "
                            "(forced-host child process runs these)")

G = 4


def quad_loss(params, batch):
    r = batch["A"] @ params["w"] - batch["b"]
    return 0.5 * jnp.sum(r ** 2)


def make_problem(key, g=G, r=8, d=40):
    ks = jax.random.split(key, 3)
    A = jax.random.normal(ks[0], (g, r, d)) / np.sqrt(d)
    w_star = jax.random.normal(ks[1], (d,))
    batch = {"A": A, "b": jnp.einsum("grd,d->gr", A, w_star)}
    params = {"w": jax.random.normal(ks[2], (d,))}
    return params, batch


def mesh8(shape=(4, 2), axes=("data", "model")):
    from jax.sharding import Mesh
    n = int(np.prod(shape))
    return Mesh(np.array(jax.devices()[:n]).reshape(shape), axes)


def mix_iter(ex, x, n_iter):
    """Iterate the exchange as a pure consensus map: feed each round's
    mixed output back in (params-only, identity/cast codecs)."""
    st = ex.init(x)
    fn = jax.jit(ex.params)
    for _ in range(n_iter):
        x, st = fn(x, None, st)
    return x, st


def mass_total(st):
    """Conserved push-sum weight mass: live counters + in-flight backlog."""
    return float(jnp.sum(st["mass"]) + jnp.sum(st["backlog_w"]))


# ---------------------------------------------------------------------------
# FaultPlan: determinism, validation, mask semantics (no exchange needed)
# ---------------------------------------------------------------------------


def test_fault_plan_deterministic_pure_in_round():
    """Masks are a pure function of (round, seed): two plan instances
    agree bit-for-bit; different rounds/seeds/hops/lanes decorrelate."""
    a = faults_mod.FaultPlan(seed=7, drop_rate=0.3, stall_rate=0.1)
    b = faults_mod.FaultPlan(seed=7, drop_rate=0.3, stall_rate=0.1)
    for rnd in (0, 1, 5):
        np.testing.assert_array_equal(
            np.asarray(a.matrix_mask(rnd, 0, 8)),
            np.asarray(b.matrix_mask(rnd, 0, 8)))
        np.testing.assert_array_equal(
            np.asarray(a.push_mask(rnd, 8)),
            np.asarray(b.push_mask(rnd, 8)))
        np.testing.assert_array_equal(
            np.asarray(a.edge_mask(rnd, 0, 1, 8)),
            np.asarray(b.edge_mask(rnd, 0, 1, 8)))
    c = faults_mod.FaultPlan(seed=8, drop_rate=0.3, stall_rate=0.1)
    diff = sum(
        not np.array_equal(np.asarray(a.push_mask(r, 64)),
                           np.asarray(c.push_mask(r, 64)))
        for r in range(8))
    assert diff >= 6   # different seed: masks decorrelate
    # round-to-round the schedule varies too
    assert not np.array_equal(np.asarray(a.matrix_mask(0, 0, 64)),
                              np.asarray(a.matrix_mask(1, 0, 64)))


def test_fault_plan_mask_semantics():
    """matrix_mask pins the diagonal (a node never loses its own value)
    and zeroes a stalled sender's column; active_mask applies dropout
    windows exactly on [r0, r1); trivial plans report so."""
    p = faults_mod.FaultPlan(seed=0, drop_rate=0.4, stall_rate=0.3)
    for rnd in range(4):
        m = np.asarray(p.matrix_mask(rnd, 0, 12))
        np.testing.assert_array_equal(np.diag(m), 1.0)
        act = np.asarray(p.active_mask(rnd, 12))
        for i in range(12):
            if act[i] == 0.0:
                off = np.delete(m[:, i], i)
                np.testing.assert_array_equal(off, 0.0)
    win = faults_mod.FaultPlan(dropouts=((2, 1, 3),))
    assert not win.trivial
    for rnd, alive in ((0, 1.0), (1, 0.0), (2, 0.0), (3, 1.0)):
        assert float(win.active_mask(rnd, G)[2]) == alive
        # absent nodes push nothing either
        assert float(win.push_mask(rnd, G)[2]) == alive
    assert faults_mod.FaultPlan().trivial
    assert faults_mod.FaultPlan(drop_rate=0.25).expected_delivery \
        == pytest.approx(0.75)
    assert faults_mod.FaultPlan(drop_rate=0.2, stall_rate=0.1) \
        .expected_delivery == pytest.approx(0.8 * 0.81)


def test_fault_plan_validates_rates():
    for bad in (dict(drop_rate=1.0), dict(drop_rate=-0.1),
                dict(stall_rate=1.5), dict(stall_rate=-1e-9)):
        with pytest.raises(ValueError, match=r"not in \[0, 1\)"):
            faults_mod.FaultPlan(**bad)


# ---------------------------------------------------------------------------
# drop_rate=0: bit-exact with the PR-5 exchange on every topology
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topology", ["server", "ring", "gossip",
                                      "async_stale", "push_sum", "none"])
def test_drop_zero_is_bit_exact_with_lossless(topology, key):
    """THE §12 no-regression gate: all-zero fault flags attach NO plan
    (trivial plans are normalized away), so every pre-existing topology
    runs literally the PR-5 code path — outputs and states identical."""
    lossless = comm.get_exchange(topology, "fp32", G, mix_rounds=2)
    zeroed = comm.get_exchange(topology, "fp32", G, mix_rounds=2,
                               drop_rate=0.0, stall_rate=0.0, fault_seed=9)
    assert zeroed.fault_plan is None
    assert zeroed.name == lossless.name      # no "+drop" tag
    assert not zeroed.faulty
    x0 = jax.random.normal(key, (G, 64))
    x = x0 + jax.random.normal(jax.random.fold_in(key, 1), x0.shape)
    st_a, st_b = lossless.init(x0), zeroed.init(x0)
    oa, sa = jax.jit(lossless.params)(x, x0, st_a)
    ob, sb = jax.jit(zeroed.params)(x, x0, st_b)
    np.testing.assert_array_equal(np.asarray(oa), np.asarray(ob))
    for la, lb in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# push_sum: ratio consensus, mass conservation, loss tolerance
# ---------------------------------------------------------------------------


def test_push_sum_lossless_converges_to_true_mean(key):
    x = jax.random.normal(key, (G, 24)) * 3.0
    want = np.asarray(jnp.mean(x, axis=0))
    ex = comm.get_exchange("push_sum", "fp32", G, mix_rounds=2)
    out, st = mix_iter(ex, x, 30)
    np.testing.assert_allclose(np.asarray(out),
                               np.broadcast_to(want, out.shape), atol=1e-5)
    assert mass_total(st) == pytest.approx(G, abs=1e-3)
    assert float(st["participation"]) == 1.0


def test_push_sum_mass_conserved_and_unbiased_under_faults(key):
    """THE §12 tentpole gate (replicated): 10% drop + 5% stall. The
    total weight mass (live + backlog) is conserved to fp32 precision
    every round, and the ratio estimate still converges to the TRUE
    group mean — loss delays mass, never destroys it."""
    x = jax.random.normal(key, (G, 24)) * 3.0
    want = np.asarray(jnp.mean(x, axis=0))
    ex = comm.get_exchange("push_sum", "fp32", G, mix_rounds=2,
                           drop_rate=0.1, stall_rate=0.05, fault_seed=1)
    assert ex.faulty and ex.stateful
    st = ex.init(x)
    fn = jax.jit(ex.params)
    out = x
    for _ in range(40):
        out, st = fn(out, None, st)
        assert mass_total(st) == pytest.approx(G, abs=1e-3)
    np.testing.assert_allclose(np.asarray(out),
                               np.broadcast_to(want, out.shape), atol=1e-4)
    assert 0.0 < float(st["participation"]) <= 1.0


def test_push_sum_cast_codec_converges_under_faults(key):
    """bf16/fp16 on the push-sum wire: the cast residue stays in the
    edge backlog (deferred, not lost) so mass stays conserved and the
    consensus error is bounded by the cast precision."""
    x = jax.random.normal(key, (G, 24))
    want = np.asarray(jnp.mean(x, axis=0))
    for codec, tol in (("bf16", 0.05), ("fp16", 0.01)):
        ex = comm.get_exchange("push_sum", codec, G, mix_rounds=2,
                               drop_rate=0.08, stall_rate=0.05,
                               fault_seed=2)
        out, st = mix_iter(ex, x, 40)
        np.testing.assert_allclose(
            np.asarray(out), np.broadcast_to(want, out.shape), atol=tol)
        assert mass_total(st) == pytest.approx(G, abs=1e-2)


def test_push_sum_elastic_membership_rejoin(key):
    """A dropout window (node absent for rounds [2, 6)) is transient
    membership churn: the absent node's mass waits, the survivors keep
    consensus among themselves, and after rejoin the full group still
    converges to the TRUE 4-node mean."""
    x = jax.random.normal(key, (G, 16)) * 2.0
    want = np.asarray(jnp.mean(x, axis=0))
    ex = comm.get_exchange("push_sum", "fp32", G, mix_rounds=1,
                           dropouts=((1, 2, 6),))
    assert ex.faulty            # dropout windows alone arm the plan
    out, st = mix_iter(ex, x, 40)
    np.testing.assert_allclose(np.asarray(out),
                               np.broadcast_to(want, out.shape), atol=1e-4)
    assert mass_total(st) == pytest.approx(G, abs=1e-3)


# ---------------------------------------------------------------------------
# bias demonstration (satellite): ring/gossip drift, push_sum doesn't
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topology", ["ring", "gossip"])
def test_lossy_mixing_biases_mean_where_push_sum_does_not(topology, key):
    """THE bias regression: under 5% deterministic drop the masked
    doubly-stochastic hop keeps rows stochastic (receivers substitute
    their own value for lost payloads — iterates stay bounded) but
    column sums break, so the group mean DRIFTS while the spread still
    contracts: the network confidently agrees on the wrong point.
    push_sum under the same fault regime stays unbiased."""
    x = jax.random.normal(key, (G, 20)) * 3.0
    mean0 = np.asarray(jnp.mean(x, axis=0))
    # seed pinned: early-round losses (spread still large) set the
    # drift magnitude, so it varies per schedule — this one drifts hard
    ex = comm.get_exchange(topology, "fp32", G, mix_rounds=1,
                           drop_rate=0.05, fault_seed=2)
    out, _ = mix_iter(ex, x, 60)
    o = np.asarray(out)
    spread = float(np.abs(o - o.mean(axis=0)).max())
    bias = float(np.abs(o.mean(axis=0) - mean0).max())
    assert spread < 1e-3, spread          # consensus reached...
    assert bias > 0.05, bias              # ...on a provably wrong point
    ps = comm.get_exchange("push_sum", "fp32", G, mix_rounds=1,
                           drop_rate=0.05, fault_seed=2)
    out_ps, _ = mix_iter(ps, x, 60)
    bias_ps = float(np.abs(np.asarray(out_ps).mean(axis=0) - mean0).max())
    assert bias_ps < 1e-4, bias_ps
    assert bias > 1e3 * bias_ps           # the headline unbias factor


def test_faulty_mixing_rows_stay_stochastic(key):
    """Graceful degradation property of the masked hop: outputs are
    convex combinations of inputs (self-substituted deficit), so a
    faulty decentralized round can never eject iterates from the convex
    hull — max/min bounds contract monotonically."""
    x = jax.random.normal(key, (G, 16)) * 5.0
    ex = comm.get_exchange("gossip", "fp32", G, mix_rounds=3,
                           drop_rate=0.3, stall_rate=0.2, fault_seed=5)
    st = ex.init(x)
    fn = jax.jit(ex.params)
    hi, lo = float(jnp.max(x)), float(jnp.min(x))
    out = x
    for _ in range(10):
        out, st = fn(out, None, st)
        assert float(jnp.max(out)) <= hi + 1e-5
        assert float(jnp.min(out)) >= lo - 1e-5


# ---------------------------------------------------------------------------
# server/async degradation: participation, retry, EF deferral
# ---------------------------------------------------------------------------


def test_faulty_server_survivor_averaging_and_participation(key):
    """Dropped pushes fall back to the group's last delivered push (the
    pushed buffer — bounded-staleness retry); participation reports the
    delivered fraction and the mix stays the mean of G buffers."""
    x0 = jax.random.normal(key, (G, 32))
    ex = comm.get_exchange("server", "fp32", G, drop_rate=0.4,
                           fault_seed=3)
    assert ex.stateful
    st = ex.init(x0)
    fn = jax.jit(ex.params)
    parts = []
    x = x0
    for rnd in range(6):
        xs = x0 + jax.random.normal(jax.random.fold_in(key, rnd),
                                    x0.shape)
        x, st = fn(xs, None, st)
        delivered = np.asarray(ex.fault_plan.push_mask(rnd, G))
        # the broadcast equals the mean of (fresh where delivered,
        # retried pushed buffer where dropped)
        np.testing.assert_array_equal(np.asarray(x[0]), np.asarray(x[1]))
        parts.append(float(st["participation"]))
        assert parts[-1] == pytest.approx(delivered.mean())
    assert min(parts) < 1.0                        # faults actually fired
    assert all(0.0 <= p <= 1.0 for p in parts)


def test_faulty_server_round_metrics_report_participation(key):
    """The localsgd round surfaces metrics['participation'] every round
    (packed path; lossless rounds report 1.0 — uniform schema,
    DESIGN.md §13)."""
    params, batch = make_problem(key)
    layout = packing.layout_of(params)
    opt = optim.packed("sgd", 0.05, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)
    ex = comm.get_exchange("server", "fp32", G, drop_rate=0.3,
                           fault_seed=1)
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg,
                                        layout=layout, exchange=ex))
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                         exchange=ex)
    seen = []
    for _ in range(5):
        st, m = rnd(st, batch)
        assert 0.0 <= float(m["participation"]) <= 1.0
        seen.append(float(m["participation"]))
    assert min(seen) < 1.0      # drop_rate=0.3 over 5 rounds: faults fired
    ex0 = comm.get_exchange("server", "fp32", G)
    rnd0 = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg,
                                         layout=layout, exchange=ex0))
    st0 = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                          exchange=ex0)
    _, m0 = rnd0(st0, batch)
    assert float(m0["participation"]) == 1.0       # lossless: always 1.0
    assert float(m0["delivery_rate"]) == 1.0


def test_ef_residual_defers_on_undelivered_push(key):
    """codecs.defer_undelivered semantics end to end: a compressed push
    that never arrived restores its shipped entries to the residual —
    residual == c exactly, as if nothing had been selected — while a
    delivered group keeps the normal EF split c == d_hat + residual."""
    x0 = jax.random.normal(key, (G, 200))
    x = x0 + jax.random.normal(jax.random.fold_in(key, 1), x0.shape)
    # deterministic fault: group 2 absent for round 0 (dropout window)
    ex = comm.get_exchange("server", "topk", G, topk_frac=0.1,
                           dropouts=((2, 0, 1),))
    st = ex.init(x0)
    out, st = jax.jit(ex.params)(x, x0, st)
    res = np.asarray(st["codec"]["params"]["residual"])
    c = np.asarray(x - x0)
    np.testing.assert_allclose(res[2], c[2], atol=1e-6)   # deferred whole
    k = max(1, round(0.1 * 200))
    for g in (0, 1, 3):
        shipped = c[g] - res[g]
        nsel = int((np.abs(shipped) > 1e-12).sum())
        assert 1 <= nsel <= k
    # next round group 2 is back: its doubled-up residual ships
    x2 = out
    out2, st2 = jax.jit(ex.params)(x2, x2, st)
    res2 = np.asarray(st2["codec"]["params"]["residual"])
    assert np.abs(res2[2]).sum() < np.abs(res[2]).sum()


def test_faulty_async_stale_bounded_and_converges(key):
    """async_stale + faults: a dropped scheduled push keeps the stale
    buffer one cycle longer (retry next schedule slot) — the round
    still converges on the convex problem and participation prices
    only the SCHEDULED pushes."""
    params, batch = make_problem(key, r=3, d=8)
    layout = packing.layout_of(params)
    opt = optim.packed("sgd", 0.2, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=4)
    ex = comm.get_exchange("async_stale", "int8", G, staleness=1,
                           drop_rate=0.15, fault_seed=2, impl="jnp")
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg,
                                        layout=layout, exchange=ex))
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                         exchange=ex)
    st, m0 = rnd(st, batch)
    for _ in range(100):
        st, m = rnd(st, batch)
        assert 0.0 <= float(m["participation"]) <= 1.0
    # int8 dither against a STALE, fault-delayed reference leaves a
    # quantization noise floor: ask for two orders, not machine zero
    assert float(jnp.mean(m["grad_sq"])) < 1e-2 * float(
        jnp.mean(m0["grad_sq"]))


def test_faulty_server_topk_now_legal_and_converges(key):
    """server+topk+faults is LEGAL (the EF residual defers undelivered
    mass — nothing is silently lost), unlike async_stale+topk whose
    schedule drops payloads by design (still refused)."""
    params, batch = make_problem(key)
    layout = packing.layout_of(params)
    opt = optim.packed("sgd", 0.3, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=4)
    ex = comm.get_exchange("server", "topk", G, topk_frac=0.2,
                           drop_rate=0.2, fault_seed=1)
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg,
                                        layout=layout, exchange=ex))
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                         exchange=ex)
    st, m0 = rnd(st, batch)
    for _ in range(150):
        st, m = rnd(st, batch)
    # sparse deltas + deferred residuals converge, just more slowly
    assert float(jnp.mean(m["grad_sq"])) < 1e-2 * float(
        jnp.mean(m0["grad_sq"]))
    with pytest.raises(NotImplementedError, match="async_stale"):
        comm.get_exchange("async_stale", "topk", G, drop_rate=0.2)


# ---------------------------------------------------------------------------
# refusal matrix (satellite): every refusal names valid alternatives
# ---------------------------------------------------------------------------


def _assert_lists_alternatives(err, *names):
    msg = str(err.value)
    assert "valid" in msg, msg
    listed = [n for n in names if f"'{n}'" in msg]
    assert len(listed) >= 2, (msg, names)


def test_every_refusal_enumerates_alternatives():
    """THE refusal-matrix gate (satellite): every get_exchange /
    mixing_matrix / get_codec refusal tells the user what WOULD work."""
    with pytest.raises(ValueError) as e:
        comm.get_exchange("bogus", "fp32", G)
    _assert_lists_alternatives(e, *comm.TOPOLOGIES)
    with pytest.raises(ValueError) as e:
        comm.get_codec("bogus")
    _assert_lists_alternatives(e, *comm.CODECS)
    with pytest.raises(ValueError) as e:
        topo.mixing_matrix("push_sum", G)
    _assert_lists_alternatives(e, "server", "ring", "gossip")
    for t in ("ring", "gossip", "push_sum", "none"):
        with pytest.raises(NotImplementedError) as e:
            comm.get_exchange(t, "fp32", G, downlink_codec="int8")
        _assert_lists_alternatives(e, "server", "async_stale")
    with pytest.raises(NotImplementedError) as e:
        comm.get_exchange("server", "fp32", G, downlink_codec="topk")
    _assert_lists_alternatives(e, "fp32", "fp16", "bf16", "int8")
    with pytest.raises(NotImplementedError) as e:
        comm.get_exchange("async_stale", "topk", G)
    _assert_lists_alternatives(e, "fp32", "fp16", "bf16", "int8")
    with pytest.raises(NotImplementedError) as e:
        comm.get_exchange("server", "fp32", G, moment_codec="topk")
    _assert_lists_alternatives(e, "fp32", "fp16", "bf16", "int8")
    for bad in ("int8", "topk"):
        with pytest.raises(NotImplementedError) as e:
            comm.get_exchange("push_sum", bad, G)
        _assert_lists_alternatives(e, "fp32", "fp16", "bf16")
        with pytest.raises(NotImplementedError) as e:
            comm.get_exchange("push_sum", "fp32", G, moment_codec=bad)
        _assert_lists_alternatives(e, "fp32", "fp16", "bf16")
    with pytest.raises(ValueError) as e:
        comm.get_exchange("none", "fp32", G, drop_rate=0.1)
    _assert_lists_alternatives(e, "server", "ring", "gossip",
                               "async_stale", "push_sum")


def test_check_comm_state_names_missing_fault_state(key):
    """The round refuses clearly when the train state misses the fault
    machinery (mass counters / pushed retry buffers)."""
    params, batch = make_problem(key)
    opt = optim.sgd(0.1)
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=1,
                              average_opt_state=False)
    ex = comm.get_exchange("push_sum", "fp32", G)
    rnd = lsgd.make_local_round(quad_loss, opt, cfg, exchange=ex)
    st = lsgd.init_state(params, opt, n_groups=G)       # no exchange=
    with pytest.raises(ValueError, match="init_state"):
        rnd(st, batch)
    st["comm"] = {"round": jnp.zeros((), jnp.int32)}     # partial state
    with pytest.raises(ValueError, match="mass"):
        rnd(st, batch)
    exf = comm.get_exchange("server", "fp32", G, drop_rate=0.2)
    rndf = lsgd.make_local_round(quad_loss, opt, cfg, exchange=exf)
    stf = lsgd.init_state(params, opt, n_groups=G)
    stf["comm"] = {"round": jnp.zeros((), jnp.int32)}
    with pytest.raises(ValueError, match="pushed"):
        rndf(stf, batch)


# ---------------------------------------------------------------------------
# wire accounting + AdaptiveT repricing
# ---------------------------------------------------------------------------


def test_push_sum_wire_prices_delivered_edges():
    """push_sum accounting: (4n + 4 weight-counter bytes) per directed
    edge payload, len(offsets)*G edges per hop, scaled by the expected
    delivery rate — dropped payloads move no bytes, the queued mass
    rides the next delivered payload at no extra width."""
    n = 32
    offs = topo.push_sum_offsets(G)
    assert offs == (1, 3)
    ex = comm.get_exchange("push_sum", "fp32", G, mix_rounds=1)
    assert ex.wire_bytes_per_round(n) == (4 * n + 4) * len(offs) * G
    lossy = comm.get_exchange("push_sum", "fp32", G, mix_rounds=1,
                              drop_rate=0.05)
    assert lossy.delivery_rate == pytest.approx(0.95)
    assert lossy.wire_bytes_per_round(n) == int(round(
        (4 * n + 4) * len(offs) * G * 0.95))
    # p2p: the (value, weight) payload counts once, not up+down
    assert lossy.wire_bytes_by_stream(n)["params"] \
        == lossy.wire_bytes_per_round(n)
    # G=2: a single offset covers both directions; G=1 has no wire
    assert topo.push_sum_offsets(2) == (1,)
    assert topo.push_sum_offsets(1) == ()
    # the name carries the fault tag for run records
    assert "+drop0.05@0" in lossy.name


def test_faulty_server_wire_prices_attempts():
    """server/ring keep attempt pricing (a dropped push occupied the
    uplink before it was lost) — the FaultPlan changes the accounted
    bytes only where queued mass genuinely coalesces (push_sum)."""
    n = 100
    for t in ("server", "ring"):
        a = comm.get_exchange(t, "fp32", G, mix_rounds=2)
        b = comm.get_exchange(t, "fp32", G, mix_rounds=2, drop_rate=0.3,
                              fault_seed=1)
        assert a.wire_bytes_per_round(n) == b.wire_bytes_per_round(n)


def test_adaptive_t_reprices_by_delivery_rate():
    """AdaptiveT.from_exchange under faults: comm is 1/delivery more
    expensive per useful round, so r shrinks by exactly the delivery
    rate and the cost-optimal T* moves UP."""
    ex0 = comm.get_exchange("server", "fp32", G)
    exf = comm.get_exchange("server", "fp32", G, drop_rate=0.2)
    c0 = AdaptiveT.from_exchange(1e-3, ex0, 10_000)
    cf = AdaptiveT.from_exchange(1e-3, exf, 10_000)
    assert exf.delivery_rate == pytest.approx(0.8)
    assert cf.r == pytest.approx(0.8 * c0.r)
    # push_sum: delivered-priced bytes / delivery == attempted bytes,
    # so its r matches its own lossless baseline exactly
    ps0 = comm.get_exchange("push_sum", "fp32", G)
    psf = comm.get_exchange("push_sum", "fp32", G, drop_rate=0.25)
    r0 = AdaptiveT.from_exchange(1e-3, ps0, 10_000).r
    rf = AdaptiveT.from_exchange(1e-3, psf, 10_000).r
    assert rf == pytest.approx(r0, rel=1e-4)
    # explicit override wins; nonsense rates refuse
    cx = AdaptiveT.from_exchange(1e-3, exf, 10_000, delivery_rate=0.5)
    assert cx.r == pytest.approx(0.5 * c0.r)
    with pytest.raises(ValueError, match="delivery_rate"):
        AdaptiveT.from_exchange(1e-3, exf, 10_000, delivery_rate=0.0)


# ---------------------------------------------------------------------------
# checkpoint: mid-fault save/resume is bit-exact (satellite)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topology,codec,kw", [
    ("async_stale", "int8", dict(staleness=1, drop_rate=0.2)),
    ("push_sum", "fp32", dict(drop_rate=0.1, stall_rate=0.05)),
    ("server", "topk", dict(drop_rate=0.25)),
])
def test_checkpoint_resume_mid_fault_bit_exact(topology, codec, kw, key,
                                               tmp_path):
    """THE mid-fault resume gate (satellite): save at round 3 with
    nonzero staleness buffers / EF residual / mass counters under an
    ACTIVE FaultPlan, resume, and the continuation is bit-exact with
    the uninterrupted run — the round counter rides the comm state and
    the masks are pure in (round, seed), so the fault schedule replays."""
    from repro.checkpoint import io as ckpt_io

    params, batch = make_problem(key)
    layout = packing.layout_of(params)
    opt = optim.packed("momentum", 0.05, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)
    ex = comm.get_exchange(topology, codec, G, fault_seed=4, impl="jnp",
                           **kw)
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg,
                                        layout=layout, exchange=ex))
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                         exchange=ex)
    for _ in range(3):
        st, _ = rnd(st, batch)
    assert int(st["comm"]["round"]) == 3   # mid-schedule, not round 0
    path = str(tmp_path / "mid_fault")
    ckpt_io.save(path, st, metadata={"round": 3, "comm": ex.name})
    back = ckpt_io.load(path, st)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for _ in range(3):
        st, _ = rnd(st, batch)            # uninterrupted
        back, _ = rnd(back, batch)        # resumed
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# 8-device mesh: sharded faulty exchange parity
# ---------------------------------------------------------------------------


def _packed_setup(key, sexec):
    params, _ = make_problem(key)
    layout = packing.shard_layout(packing.layout_of(params),
                                  sexec.n_shards)
    x0 = packing.pack(lsgd.replicate(params, G), layout)
    mask = (jnp.arange(layout.padded) < layout.size).astype(jnp.float32)
    x = x0 + jax.random.normal(jax.random.fold_in(key, 1),
                               x0.shape) * 0.1 * mask
    return layout, x0, x


@needs8
@pytest.mark.parametrize("topology,codec,kw,exact", [
    ("push_sum", "fp32", dict(mix_rounds=2, drop_rate=0.05), True),
    ("push_sum", "bf16", dict(mix_rounds=1, drop_rate=0.08,
                              stall_rate=0.05), True),
    ("server", "topk", dict(drop_rate=0.2), False),
    ("gossip", "fp32", dict(mix_rounds=2, drop_rate=0.05,
                            stall_rate=0.1), False),
    ("async_stale", "int8", dict(staleness=1, drop_rate=0.15), False),
])
def test_sharded_faulty_exchange_matches_replicated(topology, codec, kw,
                                                    exact, key):
    """THE §12 shard_map gate: the fault masks are generated OUTSIDE the
    shard_map block at full (G,)/(G,G) shape (like the int8 noise), so
    the sharded exchange consumes IDENTICAL fault schedules — push_sum
    is bit-exact with the replicated path, the rest match to reduction
    order, and the conserved mass/participation agree exactly."""
    mesh = mesh8()
    sexec = shx.plan_for(mesh)
    layout, x0, x = _packed_setup(key, sexec)
    ex = comm.get_exchange(topology, codec, G, impl="jnp", fault_seed=6,
                           **kw)
    st = ex.init(x0)
    fs = jax.jit(sexec.exchange_streams(ex, layout))
    fr = jax.jit(ex.streams)
    xs = {"params": x}
    xs0 = {} if ex.codec.identity else {"params": x0}
    os_, ss = fs(dict(xs), dict(xs0), st)
    or_, sr = fr(dict(xs), dict(xs0), st)
    a, b = np.asarray(os_["params"]), np.asarray(or_["params"])
    if exact:
        np.testing.assert_array_equal(a, b)
    elif codec == "topk":
        # sharded top-k is threshold-selected (DESIGN.md §11):
        # convergence-matched, not value-matched — near-tie entries may
        # differ, but only a boundary sliver of the selection
        close = np.abs(a - b) <= 1e-5 + 1e-5 * np.abs(b)
        assert close.mean() > 0.98, close.mean()
        np.testing.assert_allclose(a, b, atol=0.05)
    else:
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
    assert float(ss["participation"]) \
        == pytest.approx(float(sr["participation"]))
    assert int(ss["round"]) == int(sr["round"]) == 1
    if topology == "push_sum":
        np.testing.assert_array_equal(np.asarray(ss["mass"]),
                                      np.asarray(sr["mass"]))
        assert mass_total(ss) == pytest.approx(G, abs=1e-3)


@needs8
def test_sharded_push_sum_multi_round_stays_exact(key):
    """Accumulated backlog state over 8 faulty rounds: the sharded and
    replicated push-sum paths never diverge beyond per-round fp32
    rounding (same masks, same hop chain; XLA may fuse the final
    num/weight divide differently between the two jitted graphs)."""
    mesh = mesh8()
    sexec = shx.plan_for(mesh)
    layout, x0, x = _packed_setup(key, sexec)
    ex = comm.get_exchange("push_sum", "fp32", G, mix_rounds=2,
                           drop_rate=0.1, stall_rate=0.05, fault_seed=3)
    fs = jax.jit(sexec.exchange_streams(ex, layout))
    fr = jax.jit(ex.streams)
    ss = sr = ex.init(x0)
    xs_s = xs_r = x
    for _ in range(8):
        o_s, ss = fs({"params": xs_s}, {}, ss)
        o_r, sr = fr({"params": xs_r}, {}, sr)
        xs_s, xs_r = o_s["params"], o_r["params"]
        np.testing.assert_allclose(np.asarray(xs_s), np.asarray(xs_r),
                                   rtol=1e-5, atol=1e-6)
        assert mass_total(ss) == pytest.approx(G, abs=1e-3)
    for a, b in zip(jax.tree.leaves(ss), jax.tree.leaves(sr)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


@needs8
def test_builder_threads_fault_flags_sharded():
    """build_train_step threads --drop-rate/--fault-seed through to the
    exchange and allocates the push-sum mass/backlog state with
    buffer-aligned shardings (the backlog shards like the params behind
    its offset axis) — and the faulty step compiles on the mesh."""
    from repro.configs.base import InputShape, get_config
    from repro.launch.steps import build_train_step

    cfg = get_config("paper-mlp").reduced()
    mesh = mesh8()
    shape = InputShape(name="tiny", kind="train", global_batch=8,
                       seq_len=8)
    built = build_train_step(cfg, shape, mesh, t_inner=2, packed=True,
                             comm="push_sum", codec="bf16",
                             drop_rate=0.05, fault_seed=3)
    assert "+drop0.05@3" in built.meta["comm"]
    state_abs, _ = built.args
    assert {"mass", "backlog", "backlog_w", "round",
            "participation"} <= set(state_abs["comm"])
    bl = state_abs["comm"]["backlog"]["params"]
    psh = built.in_shardings[0]["params"]
    bsh = built.in_shardings[0]["comm"]["backlog"]["params"]
    assert bsh.shard_shape(tuple(bl.shape))[1:] \
        == psh.shard_shape(tuple(state_abs["params"].shape))
    with mesh:
        jitted = jax.jit(built.fn, in_shardings=built.in_shardings,
                         out_shardings=built.out_shardings,
                         donate_argnums=built.donate_argnums)
        jitted.lower(*built.args).compile()


# ---------------------------------------------------------------------------
# tier-1 driver: force 8 host devices in a child process
# ---------------------------------------------------------------------------


def test_suite_under_forced_8_devices():
    """Under the plain 1-device tier-1 run, re-run this module with 8
    forced host devices in a subprocess (jax locks the device count at
    first init). CI's forced-8-device job runs the tests directly and
    skips this driver (REPRO_SHARDEXEC_CHILD, shared with
    test_shardexec.py)."""
    if HAVE8:
        pytest.skip("already running with 8 devices")
    if os.environ.get("REPRO_SHARDEXEC_CHILD") == "1":
        pytest.skip("child process")
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                        + env.get("XLA_FLAGS", "")).strip()
    env["REPRO_SHARDEXEC_CHILD"] = "1"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(repo, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         os.path.abspath(__file__)],
        env=env, capture_output=True, text=True, timeout=1800,
        cwd=repo)
    assert r.returncode == 0, (
        f"8-device fault suite failed:\n{r.stdout[-4000:]}"
        f"\n{r.stderr[-2000:]}")
