"""Overlapped exchange + online T (ISSUE 8 / DESIGN.md §14).

Acceptance-critical invariants:
  * overlap=off IS the PR-7 engine: the flag defaults off, allocates no
    in-flight buffer, and leaves the barrier round bit-identical,
  * the overlap round implements delayed mixing exactly — a hand-rolled
    local-then-correct reference (p' = Local(p) + mix(inflight) −
    inflight, inflight' = p') reproduces the engine bit-for-bit on the
    identity codec, and a uniform start makes round 0 a pure local
    round,
  * the refusal matrix is enforced up front: overlap composes with
    server/ring/gossip × {fp32, fp16, bf16, int8, int8z} and REFUSES
    none/async_stale/push_sum, downlink re-encodes, multi-hop mixing,
    fault injection, top-k EF, and the unpacked pytree path,
  * delayed mixing still converges (the one-round lag is bounded
    staleness s=1): the convex suite reaches its gsq floor on every
    supported topology × codec cell,
  * the in-flight payload checkpoint-round-trips bit-exactly and the
    resumed run continues bit-identically to the uninterrupted one,
  * int8z (DESIGN.md §10 caveat closure) preserves exact zeros, prices
    the same wire bytes as int8, keeps jnp/pallas bit-parity, and holds
    the adamw moment streams through a lossy exchange,
  * OnlineT steers T from measured telemetry: the consensus guard
    shrinks T under weak mixing, convergence relief ramps it as
    consensus collapses, and missing signals degrade gracefully,
  * obs.exchange_phases / report gates: exposed ≤ total, the pair
    appears together, and an overlap run without the split is flagged.

8-device cells ride the same forced-host child-process pattern as
tests/test_shardexec.py (REPRO_SHARDEXEC_CHILD gates the in-suite
driver so CI's dedicated 8-device job doesn't pay twice).
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import comm, obs, optim
from repro.comm import codecs
from repro.core import controller, localsgd as lsgd
from repro.obs import report
from repro.optim import packing
from repro.sharding import shardexec as shx

HAVE8 = jax.device_count() >= 8
needs8 = pytest.mark.skipif(not HAVE8, reason="needs 8 devices "
                            "(forced-host child process runs these)")

G = 4


def quad_loss(params, batch):
    r = batch["A"] @ params["w"] - batch["b"]
    return 0.5 * jnp.sum(r ** 2)


def make_problem(key, g=G, r=8, d=40):
    ks = jax.random.split(key, 3)
    A = jax.random.normal(ks[0], (g, r, d)) / np.sqrt(d)
    w_star = jax.random.normal(ks[1], (d,))
    batch = {"A": A, "b": jnp.einsum("grd,d->gr", A, w_star)}
    params = {"w": jax.random.normal(ks[2], (d,))}
    return params, batch


def mesh8(shape=(4, 2), axes=("data", "model")):
    from jax.sharding import Mesh
    n = int(np.prod(shape))
    return Mesh(np.array(jax.devices()[:n]).reshape(shape), axes)


def _packed_round(key, topology, codec, *, opt_name="sgd", lr=0.3,
                  inner=4, overlap=True, moment_codec="fp32",
                  impl="jnp", shardexec=None, d=40):
    params, batch = make_problem(key, d=d)
    layout = packing.layout_of(params)
    if shardexec is not None:
        layout = packing.shard_layout(layout, shardexec.n_shards)
    opt = optim.packed(opt_name, lr, impl=impl)
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=inner)
    ex = comm.get_exchange(topology, codec, G, overlap=overlap,
                           moment_codec=moment_codec, impl=impl)
    rnd = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg,
                                        layout=layout, exchange=ex,
                                        shardexec=shardexec))
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                         exchange=ex)
    return rnd, st, batch, ex, layout


# ---------------------------------------------------------------------------
# overlap=off is the engine default (no behavior drift)
# ---------------------------------------------------------------------------


def test_overlap_defaults_off_and_changes_nothing(key):
    """The flag defaults off; an explicit overlap=False exchange runs
    bit-identically to the default-constructed one and allocates no
    in-flight buffer — the PR-7 barrier engine is untouched."""
    ex_def = comm.get_exchange("ring", "int8", G)
    assert ex_def.overlap is False
    assert "+ov" not in ex_def.name
    rnd_a, st_a, batch, _, _ = _packed_round(key, "ring", "int8",
                                             overlap=False)
    ex_off = comm.get_exchange("ring", "int8", G, overlap=False)
    assert "inflight" not in ex_off.init(st_a["params"])
    rnd_b, st_b, _, _, _ = _packed_round(key, "ring", "int8",
                                         overlap=False)
    for _ in range(3):
        st_a, ma = rnd_a(st_a, batch)
        st_b, mb = rnd_b(st_b, batch)
    np.testing.assert_array_equal(np.asarray(st_a["params"]),
                                  np.asarray(st_b["params"]))
    np.testing.assert_array_equal(np.asarray(ma["grad_sq"]),
                                  np.asarray(mb["grad_sq"]))


def test_overlap_names_and_inflight_state(key):
    """overlap=True tags the exchange name, and init_state allocates
    comm['inflight'] per stream, seeded with the start point (a uniform
    start → the first correction is exactly zero)."""
    params, _ = make_problem(key)
    layout = packing.layout_of(params)
    opt = optim.packed("sgd", 0.3, impl="jnp")
    ex = comm.get_exchange("server", "fp32", G, overlap=True)
    assert "+ov" in ex.name
    st = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                         exchange=ex)
    inf = st["comm"]["inflight"]
    assert set(inf) == {"params"}
    np.testing.assert_array_equal(np.asarray(inf["params"]),
                                  np.asarray(st["params"]))


# ---------------------------------------------------------------------------
# refusal matrix (DESIGN.md §14)
# ---------------------------------------------------------------------------


def test_overlap_refusal_matrix():
    """Every cell the §14 matrix refuses raises up front, with the
    valid alternatives named."""
    for topo in ("none", "async_stale", "push_sum"):
        with pytest.raises(NotImplementedError, match="overlap"):
            comm.get_exchange(topo, "fp32", G, overlap=True)
    with pytest.raises(NotImplementedError, match="downlink"):
        comm.get_exchange("server", "fp32", G, overlap=True,
                          downlink_codec="int8")
    for topo in ("ring", "gossip"):
        with pytest.raises(NotImplementedError, match="mix_rounds"):
            comm.get_exchange(topo, "fp32", G, overlap=True,
                              mix_rounds=2)
    with pytest.raises(NotImplementedError, match="fault"):
        comm.get_exchange("server", "fp32", G, overlap=True,
                          drop_rate=0.1)
    with pytest.raises(NotImplementedError, match="fault"):
        comm.get_exchange("ring", "fp32", G, overlap=True,
                          stall_rate=0.1)
    with pytest.raises(NotImplementedError, match="fault"):
        comm.get_exchange("server", "fp32", G, overlap=True,
                          dropouts=((1, 0, 2),))
    # top-k EF re-offers against a one-round-stale reference: loop gain
    # > 1 at small fractions, measured divergent — refused, not fixed
    with pytest.raises(NotImplementedError, match="topk"):
        comm.get_exchange("server", "topk", G, overlap=True)
    with pytest.raises(NotImplementedError, match="topk"):
        comm.get_exchange("server", "fp32", G, overlap=True,
                          moment_codec="topk")


def test_overlap_needs_packed_layout(key):
    """The in-flight payload is a flat stream buffer — the pytree path
    has nowhere to put it and the round builder says so."""
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=2)
    ex = comm.get_exchange("server", "fp32", G, overlap=True)
    with pytest.raises(NotImplementedError, match="inflight"):
        lsgd.make_local_round(quad_loss, optim.sgd(0.1), cfg,
                              exchange=ex)


# ---------------------------------------------------------------------------
# delayed-mixing semantics
# ---------------------------------------------------------------------------


def test_round0_uniform_start_is_pure_local(key):
    """All groups start at the same point, so the seeded in-flight
    payload is uniform, mix(inflight) == inflight, and round 0 of the
    overlap engine is bit-identical to a communication-free round."""
    rnd_ov, st_ov, batch, _, _ = _packed_round(key, "server", "fp32")
    rnd_no, st_no, _, _, _ = _packed_round(key, "none", "fp32",
                                           overlap=False)
    st_ov, _ = rnd_ov(st_ov, batch)
    st_no, _ = rnd_no(st_no, batch)
    np.testing.assert_array_equal(np.asarray(st_ov["params"]),
                                  np.asarray(st_no["params"]))


def test_delayed_mixing_matches_handrolled_reference(key):
    """THE §14 semantics gate: on the identity codec the engine's round
    is exactly p' = Local(p) + mix(inflight) − inflight with
    inflight' = p'. A hand-rolled reference that runs the engine's own
    communication-free round for Local(.) and applies the correction by
    hand reproduces the overlap engine bit-for-bit across rounds, for
    the server mean and the ring W alike."""
    for topo in ("server", "ring"):
        rnd_ov, st_ov, batch, ex, _ = _packed_round(key, topo, "fp32")
        rnd_none, st_no, _, _, _ = _packed_round(key, "none", "fp32",
                                                 overlap=False)
        # reference state: same packed buffers, no comm['inflight']
        st_ref = {"params": st_no["params"], "opt": st_no["opt"]}
        inflight = np.asarray(st_ov["comm"]["inflight"]["params"])
        mix = jax.jit(ex.mix)
        for _ in range(4):
            st_ov, _ = rnd_ov(st_ov, batch)
            # Local(p): the none-topology round on the reference state
            loc = {"params": st_ref["params"], "opt": st_ref["opt"]}
            loc, _ = rnd_none(loc, batch)
            corrected = np.asarray(loc["params"]) + (
                np.asarray(mix(jnp.asarray(inflight))) - inflight)
            st_ref = {"params": jnp.asarray(corrected), "opt": loc["opt"]}
            inflight = corrected          # identity codec ships p' itself
            np.testing.assert_array_equal(
                np.asarray(st_ov["params"]), corrected)
            np.testing.assert_array_equal(
                np.asarray(st_ov["comm"]["inflight"]["params"]),
                inflight)


@pytest.mark.parametrize("topology,codec", [
    ("server", "fp32"), ("server", "int8"), ("server", "int8z"),
    ("ring", "int8z"), ("ring", "bf16"), ("gossip", "fp32"),
])
def test_overlap_convergence_matrix(key, topology, codec):
    """Delayed mixing is bounded staleness s=1 — it converges on every
    supported topology × codec cell of the convex suite (the lag shifts
    WHEN consensus contraction lands, not whether)."""
    rnd, st, batch, _, _ = _packed_round(key, topology, codec)
    for _ in range(200):
        st, m = rnd(st, batch)
    gsq = float(jnp.mean(m["grad_sq"]))
    # the over-parameterized instance sits in the paper's sublinear
    # regime — the barrier engine measures ~5e-4 at 200 rounds here and
    # overlap tracks it (4.7–4.9e-4 across the matrix); 2e-3 is a 4x
    # margin, not a loose bound
    assert gsq < 2e-3, (topology, codec, gsq)
    assert float(jnp.mean(m["consensus_sq_post"])) < 2e-2


def test_overlap_tracks_async_stale_s1(key):
    """The documented equivalence (DESIGN.md §14): delayed mixing IS
    bounded staleness s=1 applied on every topology — both reach the
    convex-suite floor; neither stalls the other's trajectory by more
    than the staleness lag's transient."""
    rnd_ov, st_ov, batch, _, _ = _packed_round(key, "server", "fp32")
    rnd_as, st_as, _, _, _ = _packed_round(key, "async_stale", "fp32",
                                           overlap=False)
    for _ in range(200):
        st_ov, m_ov = rnd_ov(st_ov, batch)
        st_as, m_as = rnd_as(st_as, batch)
    g_ov = float(jnp.mean(m_ov["grad_sq"]))
    g_as = float(jnp.mean(m_as["grad_sq"]))
    assert g_ov < 2e-3 and g_as < 5e-3, (g_ov, g_as)
    # the lag costs at most a small constant factor, not the rate
    assert g_ov < 10 * g_as + 1e-9


# ---------------------------------------------------------------------------
# in-flight payload: checkpoint round trip mid-overlap
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topology", ["server", "ring"])
@pytest.mark.parametrize("codec", ["fp32", "int8"])
def test_inflight_checkpoint_roundtrip(key, tmp_path, topology, codec):
    """The in-flight payload (and its codec counters) survives a
    checkpoint round trip bit-exactly MID-OVERLAP, and the resumed run
    continues bit-identically to the uninterrupted one — same contract
    as the §10/§11 stream states."""
    from repro.checkpoint import io as ckpt_io

    rnd, st, batch, _, _ = _packed_round(key, topology, codec)
    for _ in range(2):
        st, _ = rnd(st, batch)
    assert "inflight" in st["comm"]
    path = str(tmp_path / f"ck_{topology}_{codec}")
    ckpt_io.save(path, st, metadata={})
    back = ckpt_io.load(path, st)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for _ in range(2):
        back, mb = rnd(back, batch)
        st, mc = rnd(st, batch)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(mc["grad_sq"]),
                                  np.asarray(mb["grad_sq"]))


# ---------------------------------------------------------------------------
# int8z: the moment-friendly zero-preserving codec (§10 caveat closure)
# ---------------------------------------------------------------------------


def test_int8z_preserves_dying_coordinates(key):
    """Sub-half-quantum elements — a dead coordinate's exponentially
    decaying moment mass — decode to EXACT zero (deterministic floor),
    while at/above half a quantum the codec keeps int8's
    stochastic-rounding semantics. Plain int8's unbiased dither kicks
    the same near-zero elements a FULL quantum off zero — exactly the
    §10 moment caveat (a quantum of m over v̂ ≈ 0 is a 1/eps-size
    step)."""
    c = codecs.get_codec("int8z", impl="jnp")
    delta = jax.random.normal(key, (G, 512))
    dead = (jnp.arange(512) % 3 == 0)
    # ~0.3 of a quantum: chunk amax ~ 3sigma so the quantum is ~0.025;
    # int8's floor(x/s + u) then kicks each dead element to a FULL
    # quantum with probability x/s ~ 0.3 — while int8z's deterministic
    # sub-half-quantum floor takes all of them to exact zero
    tiny = 8e-3
    delta = jnp.where(dead[None, :], tiny, delta)
    d_hat, _ = c.compress(delta, c.init(delta))
    np.testing.assert_array_equal(
        np.asarray(d_hat)[:, np.asarray(dead)], 0.0)
    # the live coordinates still carry mass (not zeroed wholesale)
    assert float(jnp.sum(jnp.abs(d_hat))) > 0.0
    # exact zeros are preserved too (floor(0 + u) == 0 for u < 1)
    z_hat, _ = c.compress(jnp.zeros_like(delta), c.init(delta))
    np.testing.assert_array_equal(np.asarray(z_hat), 0.0)
    # plain int8's dither kicks sub-half-quantum mass off zero — the
    # caveat int8z closes
    c8 = codecs.get_codec("int8", impl="jnp")
    d8, _ = c8.compress(delta, c8.init(delta))
    assert float(np.abs(np.asarray(d8)[:, np.asarray(dead)]).max()) > 0.0


def test_int8z_same_wire_bytes_and_impl_parity(key):
    """int8z prices exactly int8's wire (1 B/elem + fp32 chunk scales)
    and the pallas and jnp impls agree bit-for-bit (the zero mask is
    computed before the shared qdq core consumes the shared noise)."""
    n = 4096
    cz = codecs.get_codec("int8z", impl="jnp")
    c8 = codecs.get_codec("int8", impl="jnp")
    assert cz.wire_bytes(n) == c8.wire_bytes(n)
    ez = comm.get_exchange("server", "int8z", G)
    e8 = comm.get_exchange("server", "int8", G)
    assert ez.wire_bytes_per_round(n) == e8.wire_bytes_per_round(n)
    delta = jax.random.normal(key, (G, 1024)) * \
        (jnp.arange(1024) % 5 != 0)[None, :]
    cp = codecs.get_codec("int8z", impl="pallas")
    dj, _ = cz.compress(delta, cz.init(delta))
    dp, _ = cp.compress(delta, cp.init(delta))
    np.testing.assert_array_equal(np.asarray(dj), np.asarray(dp))


def test_int8z_holds_adamw_moments(key):
    """The §10 caveat closure at convergence scale: adamw with int8z
    moment streams converges on the convex suite and the second moment
    stays non-negative — dead coordinates' v stays EXACTLY dead instead
    of receiving a full-quantum kick over v̂ ≈ 0."""
    rnd, st, batch, ex, _ = _packed_round(
        key, "server", "fp32", opt_name="adamw", lr=0.05,
        moment_codec="int8z", overlap=False)
    for _ in range(200):
        st, m = rnd(st, batch)
    assert float(jnp.mean(m["grad_sq"])) < 1e-4      # measured 1.2e-5
    assert float(jnp.min(st["opt"]["v"])) >= 0.0
    # the moment wire is priced as int8 (codec_err reported per stream)
    assert "codec_err/v" in m and "codec_err/m" in m


def test_int8z_overlap_round(key):
    """int8z composes with overlap (the refusal matrix admits it where
    int8 is admitted), the moment streams ride the in-flight buffer, and
    the combined round makes progress. HONEST FLOOR: the adamw
    preconditioner riding the delayed additive correction converges
    measurably slower than the barrier round (DESIGN.md §14) — the gate
    here is monotone progress plus a coarse floor, not the barrier's."""
    rnd, st, batch, _, _ = _packed_round(key, "server", "int8z",
                                         opt_name="adamw", lr=0.05,
                                         moment_codec="int8z")
    st, m0 = rnd(st, batch)
    g0 = float(jnp.mean(m0["grad_sq"]))
    for _ in range(200):
        st, m = rnd(st, batch)
    gsq = float(jnp.mean(m["grad_sq"]))
    assert gsq < 1e-1 and gsq < g0 / 3, (gsq, g0)    # measured 2.3e-2
    assert float(jnp.min(st["opt"]["v"])) >= 0.0
    assert set(st["comm"]["inflight"]) == {"params", "m", "v"}


# ---------------------------------------------------------------------------
# OnlineT controller
# ---------------------------------------------------------------------------

TRAJ = 10.0 * 0.5 ** np.arange(8)      # clean geometric local decay


def test_onlinet_measures_cost_ratio():
    """The fenced phase times move r̂: cheap local steps relative to the
    exchange (small r) pull T* down; with no timing the prior holds."""
    c = controller.OnlineT(r=1.0, r_ema=0.0)      # no smoothing: track
    c.update(TRAJ, t_used=4, local_s=0.4, exchange_s=0.01)
    assert c.r == pytest.approx((0.4 / 4) / 0.01)  # = 10
    r_before = c.r
    c.update(TRAJ, t_used=4)                       # no timing signal
    assert c.r == r_before


def test_onlinet_consensus_guard_shrinks_t():
    """Weak mixing (consensus barely contracts, codec error mass rides
    on top) drives γ̂ up and scales the target T down vs a strong-mixing
    twin fed the same decay trajectory."""
    weak = controller.OnlineT(guard_ema=0.0, ema=0.0)
    strong = controller.OnlineT(guard_ema=0.0, ema=0.0)
    weak.update(TRAJ, t_used=4, consensus_pre=1.0,
                consensus_post=0.9, codec_err=0.2)
    strong.update(TRAJ, t_used=4, consensus_pre=1.0,
                  consensus_post=0.01)
    assert weak._gamma == pytest.approx(0.95)      # clipped
    assert strong._gamma == pytest.approx(0.01)
    # the raw EMA state carries the scaling even when both clip to the
    # same integer T at this trajectory's small T*
    assert weak._t < strong._t
    assert weak._t == pytest.approx(strong._t * (1 - 0.95) / (1 - 0.01))


def test_onlinet_convergence_relief_ramps_t():
    """As consensus mass collapses below its initial c₀ the relief
    factor sqrt(c₀/pre) ramps T (capped at relief_max) — fewer, longer
    rounds at the tail is where online-T saves wire."""
    c = controller.OnlineT(ema=0.0, guard_ema=0.0)
    c.update(TRAJ, t_used=4, consensus_pre=1.0, consensus_post=1e-4)
    t_early = c.t
    c.update(TRAJ, t_used=4, consensus_pre=1e-4, consensus_post=1e-8)
    t_late = c.t
    assert t_late > t_early
    assert c.history[-1]["relief"] <= c.relief_max
    c.update(TRAJ, t_used=4, consensus_pre=1e-12, consensus_post=0.0)
    assert c.history[-1]["relief"] == pytest.approx(c.relief_max)


def test_onlinet_divergence_guard_clamps_at_stability_edge():
    """The lr·T guard (DESIGN.md §14): consensus mass that GROWS between
    exchanges at a measured per-step exponent â, against mixing that
    only retires 1-γ̂ of it, is stable only for T < ln(1/γ̂)/â. The
    guarded controller clamps there; a clamp-disabled twin fed the
    SAME telemetry keeps T high (the multiplicative (1-γ̂) factor slows
    growth but does not bound T)."""
    guarded = controller.OnlineT(r=0.001, _t=10.0)
    loose = controller.OnlineT(r=0.001, _t=10.0, guard_margin=1e9)
    for ctl in (guarded, loose):
        c_post, t = 1.0, 10
        for _ in range(10):
            c_pre = c_post * np.exp(0.4 * t)     # drift: a = 0.4 / step
            c_post = 0.6 * c_pre                 # weak mixing: γ = 0.6
            t = ctl.update(TRAJ, t_used=t, consensus_pre=c_pre,
                           consensus_post=c_post)
    h = guarded.history[-1]
    assert h["a"] == pytest.approx(0.4, rel=0.1)
    assert h["t_guard"] is not None
    # analytic edge: 0.5 * ln(1/0.6) / 0.4 ~ 0.64 -> clamps to t_min
    assert guarded.t == guarded.t_min
    # same telemetry, clamp disabled: the (1-γ̂) factor leaves T at
    # ~0.4 * t_cost, well ABOVE the stability edge
    assert loose.t >= 3 * guarded.t
    assert loose.history[-1]["t_guard"] is not None  # computed, unbinding


def test_onlinet_guard_bounds_the_measured_divergent_config(key):
    """THE §14 caveat, lifted from docs-only to a controller guarantee:
    on the fully-determined quadratic (r=24, d=32) at lr 0.3,
    overlapped decentralized ring at static T=6 DIVERGES (consensus
    mass compounds round over round — the measured caveat), while the
    SAME config with the online controller's divergence guard driving T
    stays bounded and converges."""
    params, batch = make_problem(key, r=24, d=32)
    layout = packing.layout_of(params)
    opt = optim.packed("sgd", 0.3, impl="jnp")
    ex = comm.get_exchange("ring", "fp32", G, overlap=True, impl="jnp")
    rounds_cache = {}

    def round_for(t):
        if t not in rounds_cache:
            cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=t,
                                      metrics="traj")
            rounds_cache[t] = jax.jit(lsgd.make_local_round(
                quad_loss, opt, cfg, layout=layout, exchange=ex))
        return rounds_cache[t]

    def drive(ctl, rounds=30):
        st = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                             exchange=ex)
        t_cur, cons = 6, []
        for _ in range(rounds):
            st, m = round_for(t_cur)(st, batch)
            pre = float(jnp.mean(m["consensus_sq"]))
            cons.append(pre)
            if not np.isfinite(pre) or pre > 1e6:
                break
            if ctl is not None:
                t_cur = ctl.update(
                    np.asarray(m["grad_sq_traj"])[0],
                    t_used=int(jnp.max(m["inner_steps"])),
                    consensus_pre=pre,
                    consensus_post=float(
                        jnp.mean(m["consensus_sq_post"])))
        return cons

    static = drive(None)                 # the documented caveat: T fixed
    ctl = controller.OnlineT(r=1.0, _t=6.0)
    guarded = drive(ctl)
    assert static[-1] > 5 * static[0], static[-1]      # compounding
    assert guarded[-1] < 0.1, guarded[-1]              # converged
    assert static[-1] > 100 * guarded[-1]
    assert max(guarded) < 100 * guarded[0]
    # the guard actually engaged (not just the γ̂ scaling)
    assert any(h["t_guard"] is not None for h in ctl.history)


def test_onlinet_degrades_gracefully():
    """No telemetry at all reduces OnlineT to AdaptiveT with the prior
    r: same fitted T* core, no crash, T stays in [t_min, t_max]."""
    on = controller.OnlineT(r=2.0)
    ad = controller.AdaptiveT(r=2.0)
    for _ in range(3):
        t_on = on.update(TRAJ, t_used=4)
        t_ad = ad.update(TRAJ)
    assert t_on == t_ad
    # degenerate trajectory: fit fails, T holds its EMA state
    t_before = on.t
    assert on.update(np.ones(2), t_used=4) == t_before
    assert on.t_min <= on.t <= on.t_max


# ---------------------------------------------------------------------------
# phase fences + report gates
# ---------------------------------------------------------------------------


def test_exchange_phases_math():
    """exposed = round − local reference (floored at 0); total is the
    standalone exchange cost for overlap rounds (floored at exposed) and
    == exposed for barrier rounds, so barrier efficiency is exactly 0."""
    f = obs.exchange_phases(0.5, 0.4, 0.3, overlap=True)
    assert f["exchange_exposed"] == pytest.approx(0.1)
    assert f["exchange_total"] == pytest.approx(0.3)
    f = obs.exchange_phases(0.9, 0.4, 0.3, overlap=True)
    assert f["exchange_total"] == pytest.approx(0.5)   # floored at exposed
    f = obs.exchange_phases(0.5, 0.4, 0.0, overlap=False)
    assert f["exchange_exposed"] == f["exchange_total"]
    f = obs.exchange_phases(0.1, 0.4, 0.0, overlap=False)
    assert f["exchange_exposed"] == 0.0                # never negative


def _trace_records(phase_s, meta_extra=()):
    m = {k: 1.0 for k in obs.round_metric_keys(("params",))}
    m.update({"wire_bytes": 8, "wire_bytes_up": 8, "wire_bytes_down": 8,
              "wire_bytes/params": 8, "participation": 1.0})
    meta = {"kind": "meta", "schema": obs.SCHEMA_VERSION}
    meta.update(dict(meta_extra))
    rec = {"kind": "round", "round": 0, "phase_s": dict(phase_s),
           "metrics": m}
    return meta, [rec]


def test_report_gates_exchange_phase_pair():
    """--check: the exposed/total pair must appear together, exposed may
    not exceed total, and an overlap-meta run without the split is a
    schema problem (the overlap win would be unmeasured)."""
    ok = {"round": 0.1, "exchange_exposed": 0.02, "exchange_total": 0.05}
    assert report.check(*_trace_records(ok)) == []
    lone = {"round": 0.1, "exchange_exposed": 0.02}
    assert any("together" in s for s in report.check(*_trace_records(lone)))
    flipped = {"round": 0.1, "exchange_exposed": 0.9,
               "exchange_total": 0.1}
    assert any("exchange_total" in s
               for s in report.check(*_trace_records(flipped)))
    bare = {"round": 0.1}
    assert report.check(*_trace_records(bare)) == []
    assert any("unmeasured" in s for s in report.check(
        *_trace_records(bare, meta_extra={"overlap": True})))


def test_report_summarize_overlap_efficiency(tmp_path):
    """summarize() exposes overlap efficiency = 1 − Σexposed/Σtotal; a
    barrier trace (exposed == total) reports exactly 0."""
    meta, recs = _trace_records(
        {"round": 0.1, "exchange_exposed": 0.02, "exchange_total": 0.08})
    s = report.summarize(meta, recs)
    assert s["overlap_efficiency"] == pytest.approx(0.75)
    meta, recs = _trace_records(
        {"round": 0.1, "exchange_exposed": 0.05, "exchange_total": 0.05})
    assert report.summarize(meta, recs)["overlap_efficiency"] == 0.0
    meta, recs = _trace_records({"round": 0.1})
    assert "overlap_efficiency" not in report.summarize(meta, recs)
    path = tmp_path / "t.jsonl"
    m, r = _trace_records(
        {"round": 0.1, "exchange_exposed": 0.02, "exchange_total": 0.08},
        meta_extra={"overlap": True})
    path.write_text("\n".join(json.dumps(x) for x in [m] + r) + "\n")
    assert report.main([str(path), "--check"]) == 0
    assert report.main([str(path)]) == 0


# ---------------------------------------------------------------------------
# 8-device mesh: sharded overlap parity
# ---------------------------------------------------------------------------


@needs8
@pytest.mark.parametrize("topology,codec", [("server", "int8"),
                                            ("ring", "int8z")])
def test_sharded_overlap_matches_replicated(topology, codec, key):
    """The shard_map overlap round (encode+permute issued before the
    packed local-step block, in-flight buffer sharded like its stream)
    tracks the replicated overlap round within the engine's reduction-
    order tolerance, with identical wire accounting."""
    mesh = mesh8()
    sexec = shx.plan_for(mesh)
    rnd_s, st_s, batch, _, layout = _packed_round(
        key, topology, codec, shardexec=sexec)
    # the replicated twin runs on the SAME padded layout the shards use
    params, _ = make_problem(key)
    opt = optim.packed("sgd", 0.3, impl="jnp")
    cfg = lsgd.LocalSGDConfig(n_groups=G, inner_steps=4)
    ex = comm.get_exchange(topology, codec, G, overlap=True, impl="jnp")
    rnd_r = jax.jit(lsgd.make_local_round(quad_loss, opt, cfg,
                                          layout=layout, exchange=ex))
    st_r = lsgd.init_state(params, opt, n_groups=G, layout=layout,
                           exchange=ex)
    for _ in range(3):
        st_s, ms = rnd_s(st_s, batch)
        st_r, mr = rnd_r(st_r, batch)
    np.testing.assert_allclose(np.asarray(st_s["params"]),
                               np.asarray(st_r["params"]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(st_s["comm"]["inflight"]["params"]),
        np.asarray(st_r["comm"]["inflight"]["params"]),
        rtol=1e-5, atol=1e-6)
    assert int(ms["wire_bytes"]) == int(mr["wire_bytes"])


# ---------------------------------------------------------------------------
# tier-1 driver: force 8 host devices in a child process
# ---------------------------------------------------------------------------


def test_suite_under_forced_8_devices():
    """Under the plain 1-device tier-1 run, re-run this module's
    8-device cells with 8 forced host devices in a subprocess (jax locks
    the device count at first init). CI's forced-8-device job runs the
    tests directly and skips this driver (REPRO_SHARDEXEC_CHILD, shared
    with test_shardexec.py)."""
    if HAVE8:
        pytest.skip("already running with 8 devices")
    if os.environ.get("REPRO_SHARDEXEC_CHILD") == "1":
        pytest.skip("child process")
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                        + env.get("XLA_FLAGS", "")).strip()
    env["REPRO_SHARDEXEC_CHILD"] = "1"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(repo, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q",
         os.path.abspath(__file__),
         "-k", "sharded_overlap"],
        env=env, capture_output=True, text=True, timeout=1800,
        cwd=repo)
    assert r.returncode == 0, (
        f"8-device overlap suite failed:\n{r.stdout[-4000:]}"
        f"\n{r.stderr[-2000:]}")
